//! Process-wide metric registry: counters, gauges, and log-bucketed
//! histograms cheap enough to live on hot paths.
//!
//! Everything here is lock-free after creation: recording is relaxed
//! atomic arithmetic, and the only lock (the name → instrument map) is
//! taken once per instrument handle, never per sample. Counters are
//! sharded across cache-padded slots so concurrent lanes do not bounce
//! one cache line; shards are merged at scrape time. Histograms are
//! HDR-style: power-of-2 exponent buckets split into 16 sub-buckets,
//! which bounds relative quantile error at ~6% with a fixed 1008-slot
//! table covering the full `u64` range.
//!
//! The registry is observational only — it reads clocks and event
//! counts, never the RNG or model parameters — so recording can never
//! perturb a run's history (pinned by bit-identity tests).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Counter shards. 8 padded slots is enough to keep the bench pools
/// (≤ hardware parallelism lanes) from contending measurably.
const SHARDS: usize = 8;

/// One cache line per shard so two lanes bumping the same counter never
/// write-share a line.
#[repr(align(64))]
struct PaddedU64(AtomicU64);

impl PaddedU64 {
    fn new() -> Self {
        PaddedU64(AtomicU64::new(0))
    }
}

/// Round-robin shard assignment: each thread gets a stable slot index
/// the first time it touches any counter.
fn shard_index() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SLOT: usize = NEXT.fetch_add(1, Ordering::Relaxed) % SHARDS;
    }
    SLOT.with(|s| *s)
}

/// Monotone event counter, sharded per thread, merged at scrape.
pub struct Counter {
    shards: [PaddedU64; SHARDS],
}

impl Counter {
    fn new() -> Self {
        Counter {
            shards: std::array::from_fn(|_| PaddedU64::new()),
        }
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.shards[shard_index()].0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Merged total across all shards.
    pub fn value(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.0.load(Ordering::Relaxed))
            .sum()
    }
}

/// Last-write-wins instantaneous value (queue depths, rates).
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    fn new() -> Self {
        Gauge {
            v: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.v.store(v as u64, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, d: i64) {
        self.v.fetch_add(d as u64, Ordering::Relaxed);
    }

    /// Keep the running maximum (high-water marks).
    #[inline]
    pub fn max(&self, v: i64) {
        self.v.fetch_max(v as u64, Ordering::Relaxed);
    }

    pub fn value(&self) -> i64 {
        self.v.load(Ordering::Relaxed) as i64
    }
}

/// Sub-bucket resolution: each power-of-2 range splits into
/// `1 << SUB_BITS` linear sub-buckets.
const SUB_BITS: u32 = 4;
const SUB: u64 = 1 << SUB_BITS;
const SUB_MASK: u64 = SUB - 1;
/// Max bucket index for `u64::MAX` (exp = 63): `(63 - 4 + 1) * 16 + 15`.
const BUCKETS: usize = ((64 - SUB_BITS as usize) << SUB_BITS as usize) + SUB as usize;

/// Log-bucketed histogram over `u64` sample values (nanoseconds for
/// durations). Recording is two relaxed `fetch_add`s plus a
/// `fetch_max`; quantiles are estimated from bucket lower bounds at
/// scrape time (≤ 1/16 relative error by construction).
pub struct Histogram {
    buckets: Box<[AtomicU64; BUCKETS]>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

/// Bucket index for a sample value.
#[inline]
fn bucket_of(v: u64) -> usize {
    if v < SUB {
        v as usize
    } else {
        let exp = 63 - v.leading_zeros(); // >= SUB_BITS
        let shift = exp - SUB_BITS;
        (((shift as usize + 1) << SUB_BITS) | ((v >> shift) & SUB_MASK) as usize)
            .min(BUCKETS - 1)
    }
}

/// Smallest value that lands in bucket `b` (the quantile estimate).
fn bucket_lower(b: usize) -> u64 {
    if b < SUB as usize {
        b as u64
    } else {
        let shift = (b >> SUB_BITS) as u32 - 1;
        let sub = (b as u64) & SUB_MASK;
        (SUB | sub) << shift
    }
}

impl Histogram {
    fn new() -> Self {
        // `AtomicU64` is not Copy; build the boxed array in place.
        let buckets: Box<[AtomicU64; BUCKETS]> = (0..BUCKETS)
            .map(|_| AtomicU64::new(0))
            .collect::<Vec<_>>()
            .try_into()
            .unwrap_or_else(|_| unreachable!("length fixed at BUCKETS"));
        Histogram {
            buckets,
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one raw sample.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record a duration in seconds (stored as integer nanoseconds).
    /// Rejects NaN and negative values — telemetry must never panic a
    /// run; a nonsense clock reading is dropped, not recorded.
    #[inline]
    pub fn record_secs(&self, secs: f64) {
        if !secs.is_finite() || secs < 0.0 {
            return;
        }
        // Saturate rather than wrap for absurdly long durations.
        let ns = (secs * 1e9).min(u64::MAX as f64) as u64;
        self.record(ns);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn max_value(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Estimated q-quantile (q in [0, 1]) from bucket lower bounds.
    /// Returns 0 for an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (b, slot) in self.buckets.iter().enumerate() {
            seen += slot.load(Ordering::Relaxed);
            if seen >= rank {
                return bucket_lower(b);
            }
        }
        self.max_value()
    }

    /// Summary snapshot as deterministic JSON.
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        let count = self.count();
        o.set("count", Json::from(count));
        o.set("sum_ns", Json::from(self.sum()));
        if count > 0 {
            o.set("mean_ns", Json::from(self.sum() as f64 / count as f64));
            o.set("p50_ns", Json::from(self.quantile(0.50)));
            o.set("p95_ns", Json::from(self.quantile(0.95)));
            o.set("p99_ns", Json::from(self.quantile(0.99)));
            o.set("max_ns", Json::from(self.max_value()));
        }
        o
    }
}

/// Name → instrument maps. Handles are `Arc`s so hot paths resolve a
/// name once and record lock-free forever after.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    /// Get or create the named counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.counters.lock().unwrap_or_else(|e| e.into_inner());
        match m.get(name) {
            Some(c) => c.clone(),
            None => {
                let c = Arc::new(Counter::new());
                m.insert(name.to_string(), c.clone());
                c
            }
        }
    }

    /// Get or create the named gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.gauges.lock().unwrap_or_else(|e| e.into_inner());
        match m.get(name) {
            Some(g) => g.clone(),
            None => {
                let g = Arc::new(Gauge::new());
                m.insert(name.to_string(), g.clone());
                g
            }
        }
    }

    /// Get or create the named histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.hists.lock().unwrap_or_else(|e| e.into_inner());
        match m.get(name) {
            Some(h) => h.clone(),
            None => {
                let h = Arc::new(Histogram::new());
                m.insert(name.to_string(), h.clone());
                h
            }
        }
    }

    /// Full snapshot: `{"counters": {...}, "gauges": {...},
    /// "histograms": {...}}`, deterministically ordered (BTreeMap).
    pub fn snapshot(&self) -> Json {
        let mut counters = Json::obj();
        for (name, c) in self.counters.lock().unwrap_or_else(|e| e.into_inner()).iter() {
            counters.set(name, Json::from(c.value()));
        }
        let mut gauges = Json::obj();
        for (name, g) in self.gauges.lock().unwrap_or_else(|e| e.into_inner()).iter() {
            gauges.set(name, Json::from(g.value() as f64));
        }
        let mut hists = Json::obj();
        for (name, h) in self.hists.lock().unwrap_or_else(|e| e.into_inner()).iter() {
            hists.set(name, h.to_json());
        }
        let mut o = Json::obj();
        o.set("counters", counters);
        o.set("gauges", gauges);
        o.set("histograms", hists);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_sharded_totals_exact() {
        // N threads hammer one counter; the merged total is exact.
        let reg = Registry::new();
        let c = reg.counter("hits");
        let threads = 8;
        let per = 50_000u64;
        std::thread::scope(|s| {
            for _ in 0..threads {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..per {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.value(), threads * per);
        // the registry hands back the same instrument
        assert_eq!(reg.counter("hits").value(), threads * per);
    }

    #[test]
    fn histogram_concurrent_totals_exact() {
        let reg = Registry::new();
        let h = reg.histogram("lat");
        let threads = 8u64;
        let per = 20_000u64;
        std::thread::scope(|s| {
            for t in 0..threads {
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..per {
                        h.record(t * per + i);
                    }
                });
            }
        });
        assert_eq!(h.count(), threads * per);
        let expect_sum: u64 = (0..threads * per).sum();
        assert_eq!(h.sum(), expect_sum);
        assert_eq!(h.max_value(), threads * per - 1);
    }

    #[test]
    fn histogram_bucket_boundaries() {
        // exact below SUB; every value maps into a bucket whose lower
        // bound is <= v and within 1/16 relative error above.
        for v in 0..SUB {
            assert_eq!(bucket_of(v), v as usize);
            assert_eq!(bucket_lower(bucket_of(v)), v);
        }
        for &v in &[
            SUB,
            SUB + 1,
            255,
            256,
            257,
            1 << 20,
            (1 << 20) + 12_345,
            u64::MAX / 2,
            u64::MAX,
        ] {
            let b = bucket_of(v);
            assert!(b < BUCKETS, "bucket {b} out of range for {v}");
            let lo = bucket_lower(b);
            assert!(lo <= v, "lower bound {lo} above sample {v}");
            // next bucket starts above v
            if b + 1 < BUCKETS {
                assert!(bucket_lower(b + 1) > v, "value {v} misfiled in bucket {b}");
            }
        }
        // zero lands in bucket 0 with lower bound 0
        let h = Histogram::new();
        h.record(0);
        assert_eq!(h.quantile(0.5), 0);
        // u64::MAX saturates into the last bucket without panicking
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn histogram_rejects_nan_and_negative() {
        let h = Histogram::new();
        h.record_secs(f64::NAN);
        h.record_secs(-1.0);
        h.record_secs(f64::NEG_INFINITY);
        h.record_secs(f64::INFINITY);
        assert_eq!(h.count(), 0, "invalid durations must be dropped");
        h.record_secs(0.5);
        assert_eq!(h.count(), 1);
        assert!(h.sum() >= 499_000_000 && h.sum() <= 501_000_000);
    }

    #[test]
    fn quantiles_land_near_true_values() {
        let h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        for &(q, expect) in &[(0.5, 5_000.0), (0.95, 9_500.0), (0.99, 9_900.0)] {
            let got = h.quantile(q) as f64;
            let rel = (got - expect).abs() / expect;
            assert!(rel < 0.08, "q{q}: got {got}, want ~{expect} (rel {rel:.3})");
        }
    }

    #[test]
    fn snapshot_deterministic_and_complete() {
        let reg = Registry::new();
        reg.counter("b").add(2);
        reg.counter("a").add(1);
        reg.gauge("depth").set(7);
        reg.histogram("lat").record(1_000);
        let a = reg.snapshot().to_string();
        let b = reg.snapshot().to_string();
        assert_eq!(a, b);
        assert!(a.find("\"a\"").unwrap() < a.find("\"b\"").unwrap(), "sorted keys");
        let parsed = Json::parse(&a).unwrap();
        assert_eq!(parsed.path("counters.a").and_then(Json::as_f64), Some(1.0));
        assert_eq!(parsed.path("gauges.depth").and_then(Json::as_f64), Some(7.0));
        assert_eq!(
            parsed.path("histograms.lat.count").and_then(Json::as_f64),
            Some(1.0)
        );
    }
}
