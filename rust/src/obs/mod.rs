//! Straggler-centric telemetry: metric registry, phase spans, and trace
//! export.
//!
//! The paper's whole argument is a time decomposition — each iteration
//! is the wait for the `n_i − b_i` fastest neighbours plus compute plus
//! mixing — and this module makes that decomposition observable across
//! every layer: the engine pool, the live TCP driver, the comms
//! transport, and the DES.
//!
//! Three pieces:
//! - [`registry`] — process-wide counters / gauges / log-bucketed
//!   histograms (relaxed atomics; cheap enough for hot paths).
//! - [`span`] — RAII phase spans (`wait`, `compute`, `mix`, `comms`,
//!   `eval`, `ckpt`) recording into the registry and, when a trace sink
//!   is attached, into:
//! - [`trace`] — a streamed JSONL event file exported as a Chrome
//!   `trace_event` (Perfetto-loadable) timeline, one track per
//!   worker/lane.
//!
//! **Hard invariant:** telemetry reads clocks but never the RNG or the
//! parameters. An instrumented run's exported history is byte-identical
//! to the uninstrumented run (pinned by tests and the `obs-smoke` CI
//! job), and with no observer installed the per-sample cost is one
//! relaxed atomic load.

pub mod registry;
pub mod report;
pub mod span;
pub mod trace;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use registry::Registry;
use trace::TraceSink;

use crate::util::json::Json;

/// Metrics snapshot file name inside the obs dir.
pub const METRICS_JSON: &str = "metrics.json";

/// One observation context: a registry plus an optional trace sink.
pub struct Obs {
    pub registry: Arc<Registry>,
    trace: Option<TraceSink>,
    dir: Option<PathBuf>,
    t0: Instant,
}

impl Obs {
    /// Full observer: registry + streamed trace under `dir` (created if
    /// missing). `finish` writes `metrics.json` and `trace.json` there.
    pub fn to_dir(dir: &Path) -> anyhow::Result<Arc<Obs>> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("create obs dir {}: {e}", dir.display()))?;
        Ok(Arc::new(Obs {
            registry: Arc::new(Registry::new()),
            trace: Some(TraceSink::create(dir)?),
            dir: Some(dir.to_path_buf()),
            t0: Instant::now(),
        }))
    }

    /// Registry only — no trace I/O. Used by the `obs/overhead` bench
    /// to price the hot-path instrumentation itself.
    pub fn registry_only() -> Arc<Obs> {
        Arc::new(Obs {
            registry: Arc::new(Registry::new()),
            trace: None,
            dir: None,
            t0: Instant::now(),
        })
    }

    /// The trace sink, when this observer records one.
    pub fn trace(&self) -> Option<&TraceSink> {
        self.trace.as_ref()
    }

    /// Wall-clock microseconds since this observer was created (the
    /// trace time base for live runs).
    pub fn now_us(&self) -> u64 {
        self.t0.elapsed().as_micros() as u64
    }

    /// Registry snapshot as JSON (exposed for tests and `finish`).
    pub fn snapshot(&self) -> Json {
        self.registry.snapshot()
    }

    /// Flush everything: write `metrics.json` and export the Chrome
    /// trace next to the JSONL stream. No-op without a directory.
    pub fn finish(&self) -> anyhow::Result<()> {
        let Some(dir) = &self.dir else {
            return Ok(());
        };
        if let Some(sink) = &self.trace {
            sink.finish()?;
        }
        let path = dir.join(METRICS_JSON);
        std::fs::write(&path, self.snapshot().to_string_pretty())
            .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
        Ok(())
    }
}

/// Fast-path switch: a single relaxed load answers "is anyone
/// watching?" before any instrumentation work happens.
static ENABLED: AtomicBool = AtomicBool::new(false);
static GLOBAL: Mutex<Option<Arc<Obs>>> = Mutex::new(None);

/// Install `obs` as the process-wide observer.
pub fn install(obs: Arc<Obs>) {
    *GLOBAL.lock().unwrap_or_else(|e| e.into_inner()) = Some(obs);
    ENABLED.store(true, Ordering::Release);
}

/// Remove and return the process-wide observer (if any).
pub fn uninstall() -> Option<Arc<Obs>> {
    ENABLED.store(false, Ordering::Release);
    GLOBAL.lock().unwrap_or_else(|e| e.into_inner()).take()
}

/// Is a process-wide observer installed? One relaxed atomic load —
/// this is the entire cost of instrumentation when observability is
/// off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The process-wide observer, if installed.
pub fn active() -> Option<Arc<Obs>> {
    if !enabled() {
        return None;
    }
    GLOBAL.lock().unwrap_or_else(|e| e.into_inner()).clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_only_finish_is_noop() {
        let obs = Obs::registry_only();
        obs.registry.counter("x").inc();
        obs.finish().unwrap(); // no dir: nothing written, no error
        assert!(obs.trace().is_none());
    }

    #[test]
    fn to_dir_writes_metrics_and_trace() {
        let dir = std::env::temp_dir().join(format!("dybw-obs-mod-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let obs = Obs::to_dir(&dir).unwrap();
        obs.registry.counter("events").add(3);
        obs.trace().unwrap().complete("worker-0", "compute", 0, 10, &[]);
        obs.finish().unwrap();
        let metrics = Json::parse(&std::fs::read_to_string(dir.join(METRICS_JSON)).unwrap()).unwrap();
        assert_eq!(metrics.path("counters.events").and_then(Json::as_f64), Some(3.0));
        let chrome =
            Json::parse(&std::fs::read_to_string(dir.join(trace::TRACE_JSON)).unwrap()).unwrap();
        assert!(chrome.get("traceEvents").and_then(Json::as_arr).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
