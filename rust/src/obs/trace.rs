//! Streamed trace sink: one JSONL event per line while the run is
//! live, exported as a Chrome `trace_event` file (loadable in
//! about://tracing or Perfetto) at shutdown.
//!
//! Every line is itself a complete Chrome event object — "X" (complete)
//! events with microsecond `ts`/`dur`, one `tid` per track (worker,
//! lane, leader…) — so `trace.json` is just the lines joined inside
//! `{"traceEvents": [...]}` plus thread-name metadata. Timestamps come
//! from the wall clock for live runs and from the DES virtual clock for
//! simulated runs; either way they are *read-only* observations, so the
//! sink can never perturb the run it is recording.

use std::collections::HashMap;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::util::json::Json;

/// Streaming JSONL file name inside the obs dir.
pub const TRACE_JSONL: &str = "trace.jsonl";
/// Chrome `trace_event` export file name inside the obs dir.
pub const TRACE_JSON: &str = "trace.json";

struct Inner {
    w: BufWriter<File>,
    /// Track name → Chrome tid, in first-seen order.
    tids: HashMap<String, u64>,
    /// (tid, name) pairs in assignment order, for metadata export.
    names: Vec<(u64, String)>,
}

/// Append-only trace event writer. All methods take `&self`; the file
/// is behind one mutex (trace volume is per-iteration, not per-sample,
/// so contention is negligible and the sink stays `Sync`).
pub struct TraceSink {
    inner: Mutex<Inner>,
    path: PathBuf,
}

impl TraceSink {
    /// Create (truncate) `dir/trace.jsonl`.
    pub fn create(dir: &Path) -> anyhow::Result<TraceSink> {
        let path = dir.join(TRACE_JSONL);
        let f = File::create(&path)
            .map_err(|e| anyhow::anyhow!("create {}: {e}", path.display()))?;
        Ok(TraceSink {
            inner: Mutex::new(Inner {
                w: BufWriter::new(f),
                tids: HashMap::new(),
                names: Vec::new(),
            }),
            path,
        })
    }

    /// Emit one complete ("X") event on `track`. `ts_us`/`dur_us` are
    /// microseconds; `args` become the Chrome `args` object.
    pub fn complete(&self, track: &str, name: &str, ts_us: u64, dur_us: u64, args: &[(&str, f64)]) {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let next = inner.tids.len() as u64;
        let tid = match inner.tids.get(track) {
            Some(&t) => t,
            None => {
                inner.tids.insert(track.to_string(), next);
                inner.names.push((next, track.to_string()));
                next
            }
        };
        let mut ev = Json::obj();
        ev.set("name", Json::from(name));
        // `cat` carries the track name on every line so JSONL consumers
        // (`dybw obs report`) can group without the tid metadata table.
        ev.set("cat", Json::from(track));
        ev.set("ph", Json::from("X"));
        ev.set("ts", Json::from(ts_us));
        ev.set("dur", Json::from(dur_us));
        ev.set("pid", Json::from(0u64));
        ev.set("tid", Json::from(tid));
        if !args.is_empty() {
            let mut a = Json::obj();
            for (k, v) in args {
                a.set(k, Json::from(*v));
            }
            ev.set("args", a);
        }
        // Telemetry IO failures must never abort the run they observe.
        let line = ev.to_string();
        let _ = inner.w.write_all(line.as_bytes());
        let _ = inner.w.write_all(b"\n");
    }

    /// Emit an instant ("i") event — a point in time with no duration
    /// (worker down, reconnect, rejoin…).
    pub fn instant(&self, track: &str, name: &str, ts_us: u64) {
        self.complete(track, name, ts_us, 0, &[]);
    }

    /// Flush the JSONL stream and write the Chrome `trace_event` export
    /// next to it: thread-name metadata events followed by every
    /// streamed line, wrapped in `{"traceEvents": [...]}`.
    pub fn finish(&self) -> anyhow::Result<PathBuf> {
        let (names, jsonl_path) = {
            let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
            inner.w.flush()?;
            (inner.names.clone(), self.path.clone())
        };
        let out_path = jsonl_path.with_file_name(TRACE_JSON);
        let out = File::create(&out_path)
            .map_err(|e| anyhow::anyhow!("create {}: {e}", out_path.display()))?;
        let mut w = BufWriter::new(out);
        w.write_all(b"{\"traceEvents\":[")?;
        let mut first = true;
        for (tid, track) in &names {
            let mut md = Json::obj();
            md.set("name", Json::from("thread_name"));
            md.set("ph", Json::from("M"));
            md.set("pid", Json::from(0u64));
            md.set("tid", Json::from(*tid));
            let mut a = Json::obj();
            a.set("name", Json::from(track.as_str()));
            md.set("args", a);
            if !first {
                w.write_all(b",")?;
            }
            first = false;
            w.write_all(md.to_string().as_bytes())?;
        }
        let f = File::open(&jsonl_path)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", jsonl_path.display()))?;
        for line in BufReader::new(f).lines() {
            let line = line?;
            if line.is_empty() {
                continue;
            }
            if !first {
                w.write_all(b",")?;
            }
            first = false;
            w.write_all(line.as_bytes())?;
        }
        w.write_all(b"]}")?;
        w.flush()?;
        Ok(out_path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("dybw-obs-trace-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn jsonl_lines_parse_and_chrome_export_is_valid() {
        let dir = tmpdir("basic");
        let sink = TraceSink::create(&dir).unwrap();
        sink.complete("worker-0", "compute", 10, 90, &[("k", 1.0)]);
        sink.complete("worker-1", "wait", 100, 25, &[]);
        sink.instant("leader", "reconnect", 130);
        let out = sink.finish().unwrap();

        let jsonl = std::fs::read_to_string(dir.join(TRACE_JSONL)).unwrap();
        for line in jsonl.lines() {
            let ev = Json::parse(line).expect("every JSONL line parses");
            assert!(ev.get("name").is_some() && ev.get("ts").is_some());
        }
        assert_eq!(jsonl.lines().count(), 3);

        let chrome = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let events = chrome.get("traceEvents").and_then(Json::as_arr).unwrap();
        // 3 thread_name metadata events + 3 recorded events
        assert_eq!(events.len(), 6);
        let md: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .collect();
        assert_eq!(md.len(), 3);
        assert!(md.iter().any(|e| {
            e.path("args.name").and_then(Json::as_str) == Some("worker-0")
        }));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn worker_names_json_escaped() {
        // Hostile track/arg names must escape cleanly (quotes,
        // backslashes, control characters).
        let dir = tmpdir("escape");
        let sink = TraceSink::create(&dir).unwrap();
        let evil = "worker \"7\"\\rack\nA\tend";
        sink.complete(evil, "compute", 0, 5, &[]);
        let out = sink.finish().unwrap();

        let jsonl = std::fs::read_to_string(dir.join(TRACE_JSONL)).unwrap();
        for line in jsonl.lines() {
            Json::parse(line).expect("escaped line parses");
        }
        let chrome = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let events = chrome.get("traceEvents").and_then(Json::as_arr).unwrap();
        let roundtrip = events
            .iter()
            .find_map(|e| {
                (e.get("ph").and_then(Json::as_str) == Some("M"))
                    .then(|| e.path("args.name").and_then(Json::as_str))
                    .flatten()
            })
            .unwrap();
        assert_eq!(roundtrip, evil, "track name survives escaping round-trip");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stable_tids_per_track() {
        let dir = tmpdir("tids");
        let sink = TraceSink::create(&dir).unwrap();
        sink.complete("a", "x", 0, 1, &[]);
        sink.complete("b", "x", 1, 1, &[]);
        sink.complete("a", "y", 2, 1, &[]);
        sink.finish().unwrap();
        let jsonl = std::fs::read_to_string(dir.join(TRACE_JSONL)).unwrap();
        let tids: Vec<f64> = jsonl
            .lines()
            .map(|l| Json::parse(l).unwrap().get("tid").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(tids, vec![0.0, 1.0, 0.0]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
