//! `dybw obs report` — the straggler decomposition table.
//!
//! Reads a recorded obs directory (`trace.jsonl` + `metrics.json`) and
//! prints, per track (worker), the p50/p95/p99 of the paper's three
//! phases — wait, compute, mix — the worker's share of total wait time,
//! and the realised backup counts `b_i(k)` against the policy's chosen
//! allowance. This is the observable the whole DBW argument rests on:
//! the wait term is what dynamic backup workers exist to shrink.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::Path;

use crate::util::json::Json;

#[derive(Default)]
struct TrackStats {
    wait_us: Vec<f64>,
    compute_us: Vec<f64>,
    mix_us: Vec<f64>,
    b: Vec<f64>,
    b_chosen: Vec<f64>,
}

impl TrackStats {
    fn samples(&self) -> usize {
        self.wait_us
            .len()
            .max(self.compute_us.len())
            .max(self.mix_us.len())
    }
}

/// Exact quantile of an unsorted sample set (sorts a copy).
fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let idx = ((q * (v.len() - 1) as f64).round() as usize).min(v.len() - 1);
    v[idx]
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

fn fmt_ms(us: f64) -> String {
    format!("{:.2}", us / 1e3)
}

fn p_cell(xs: &[f64]) -> String {
    if xs.is_empty() {
        return "-".into();
    }
    format!(
        "{}/{}/{}",
        fmt_ms(quantile(xs, 0.50)),
        fmt_ms(quantile(xs, 0.95)),
        fmt_ms(quantile(xs, 0.99))
    )
}

/// Build the report text for a recorded obs directory.
pub fn report(dir: &Path, top_k: usize) -> anyhow::Result<String> {
    let jsonl = dir.join(super::trace::TRACE_JSONL);
    let mut tracks: BTreeMap<String, TrackStats> = BTreeMap::new();
    if jsonl.exists() {
        let f = File::open(&jsonl)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", jsonl.display()))?;
        for (lineno, line) in BufReader::new(f).lines().enumerate() {
            let line = line?;
            if line.is_empty() {
                continue;
            }
            let ev = Json::parse(&line).map_err(|e| {
                anyhow::anyhow!("{}:{}: bad trace line: {e:?}", jsonl.display(), lineno + 1)
            })?;
            let (Some(track), Some(name)) = (
                ev.get("cat").and_then(Json::as_str),
                ev.get("name").and_then(Json::as_str),
            ) else {
                continue;
            };
            let dur = ev.get("dur").and_then(Json::as_f64).unwrap_or(0.0);
            let st = tracks.entry(track.to_string()).or_default();
            match name {
                "wait" => st.wait_us.push(dur),
                "compute" => st.compute_us.push(dur),
                "mix" => {
                    st.mix_us.push(dur);
                    if let Some(b) = ev.path("args.b").and_then(Json::as_f64) {
                        st.b.push(b);
                    }
                    if let Some(bc) = ev.path("args.b_chosen").and_then(Json::as_f64) {
                        st.b_chosen.push(bc);
                    }
                }
                _ => {}
            }
        }
    }

    let mut out = String::new();
    out.push_str(&format!("obs report: {}\n", dir.display()));

    let decomposed: Vec<(&String, &TrackStats)> = tracks
        .iter()
        .filter(|(_, st)| st.samples() > 0)
        .collect();
    if decomposed.is_empty() {
        out.push_str("no phase events recorded (was the run traced with --obs-dir?)\n");
    } else {
        let name_w = decomposed
            .iter()
            .map(|(t, _)| t.len())
            .max()
            .unwrap()
            .max("track".len());
        let total_wait: f64 = decomposed.iter().map(|(_, st)| st.wait_us.iter().sum::<f64>()).sum();
        out.push_str("\nper-track phase decomposition (p50/p95/p99, ms):\n");
        out.push_str(&format!(
            "{:<name_w$}  {:>5}  {:>20}  {:>20}  {:>20}  {:>6}  {:>11}\n",
            "track", "n", "wait", "compute", "mix", "wait%", "b mean/max"
        ));
        for (track, st) in &decomposed {
            let wait_sum: f64 = st.wait_us.iter().sum();
            let share = if total_wait > 0.0 {
                100.0 * wait_sum / total_wait
            } else {
                0.0
            };
            let b_cell = if st.b.is_empty() {
                "-".into()
            } else {
                format!(
                    "{:.2}/{:.0}",
                    mean(&st.b),
                    st.b.iter().cloned().fold(0.0, f64::max)
                )
            };
            out.push_str(&format!(
                "{:<name_w$}  {:>5}  {:>20}  {:>20}  {:>20}  {:>5.1}%  {:>11}\n",
                track,
                st.samples(),
                p_cell(&st.wait_us),
                p_cell(&st.compute_us),
                p_cell(&st.mix_us),
                share,
                b_cell
            ));
        }

        // top-k stragglers: the tracks the cluster waits on the least —
        // i.e. the SLOW workers, which show up as everyone else's wait.
        // A worker's own wait being small means it finished late; rank
        // by (high compute, low wait share).
        let mut by_compute: Vec<(&String, f64, f64)> = decomposed
            .iter()
            .map(|(t, st)| {
                let c: f64 = st.compute_us.iter().sum();
                let w: f64 = st.wait_us.iter().sum();
                (*t, c, w)
            })
            .collect();
        by_compute.sort_by(|a, b| f64::total_cmp(&b.1, &a.1));
        out.push_str(&format!("\ntop-{top_k} stragglers (by total compute time):\n"));
        for (t, c, w) in by_compute.iter().take(top_k) {
            out.push_str(&format!(
                "  {:<name_w$}  compute {:>10.2}ms  own-wait {:>10.2}ms\n",
                t,
                c / 1e3,
                w / 1e3
            ));
        }

        let all_b: Vec<f64> = decomposed.iter().flat_map(|(_, st)| st.b.iter().cloned()).collect();
        let all_bc: Vec<f64> = decomposed
            .iter()
            .flat_map(|(_, st)| st.b_chosen.iter().cloned())
            .collect();
        if !all_b.is_empty() {
            out.push_str(&format!(
                "\nrealised backup counts b_i(k): mean {:.3}  p95 {:.0}  max {:.0}  (n={})\n",
                mean(&all_b),
                quantile(&all_b, 0.95),
                all_b.iter().cloned().fold(0.0, f64::max),
                all_b.len()
            ));
            if !all_bc.is_empty() {
                out.push_str(&format!(
                    "policy's chosen allowance b*: mean {:.3}  (realised/chosen = {:.2})\n",
                    mean(&all_bc),
                    if mean(&all_bc) > 0.0 {
                        mean(&all_b) / mean(&all_bc)
                    } else {
                        0.0
                    }
                ));
            }
        }
    }

    // registry snapshot summary, when present
    let metrics = dir.join(super::METRICS_JSON);
    if metrics.exists() {
        let j = Json::parse(
            &std::fs::read_to_string(&metrics)
                .map_err(|e| anyhow::anyhow!("read {}: {e}", metrics.display()))?,
        )
        .map_err(|e| anyhow::anyhow!("{}: bad JSON: {e:?}", metrics.display()))?;
        out.push_str("\nregistry snapshot (metrics.json):\n");
        for section in ["counters", "gauges"] {
            if let Some(Json::Obj(m)) = j.get(section) {
                for (k, v) in m {
                    if let Some(n) = v.as_f64() {
                        out.push_str(&format!("  {k:<32} {n}\n"));
                    }
                }
            }
        }
        if let Some(Json::Obj(m)) = j.get("histograms") {
            for (k, v) in m {
                let count = v.get("count").and_then(Json::as_f64).unwrap_or(0.0);
                if count == 0.0 {
                    continue;
                }
                let ms = |f: &str| v.get(f).and_then(Json::as_f64).unwrap_or(0.0) / 1e6;
                out.push_str(&format!(
                    "  {k:<32} n={count}  p50 {:.3}ms  p95 {:.3}ms  p99 {:.3}ms  max {:.3}ms\n",
                    ms("p50_ns"),
                    ms("p95_ns"),
                    ms("p99_ns"),
                    ms("max_ns"),
                ));
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::TraceSink;

    #[test]
    fn report_decomposes_per_worker() {
        let dir = std::env::temp_dir().join(format!("dybw-obs-report-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let sink = TraceSink::create(&dir).unwrap();
        for k in 1..=20u64 {
            for w in 0..3u64 {
                let base = k * 1000;
                sink.complete(&format!("dybw/worker-{w}"), "compute", base, 400 + w * 100, &[]);
                sink.complete(&format!("dybw/worker-{w}"), "wait", base + 500, 300 - w * 100, &[]);
                sink.complete(
                    &format!("dybw/worker-{w}"),
                    "mix",
                    base + 900,
                    0,
                    &[("k", k as f64), ("b", w as f64), ("b_chosen", 2.0)],
                );
            }
        }
        sink.finish().unwrap();
        let text = report(&dir, 2).unwrap();
        for w in 0..3 {
            assert!(text.contains(&format!("dybw/worker-{w}")), "missing worker {w}:\n{text}");
        }
        assert!(text.contains("wait"), "{text}");
        assert!(text.contains("realised backup counts"), "{text}");
        assert!(text.contains("chosen allowance"), "{text}");
        assert!(text.contains("top-2 stragglers"), "{text}");
        // worker-2 has the longest compute => ranked first straggler
        let straggler_section = text.split("stragglers").nth(1).unwrap();
        let first = straggler_section.lines().nth(1).unwrap();
        assert!(first.contains("worker-2"), "expected worker-2 first: {first}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn report_on_empty_dir_is_graceful() {
        let dir = std::env::temp_dir().join(format!("dybw-obs-report-empty-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let text = report(&dir, 5).unwrap();
        assert!(text.contains("no phase events recorded"), "{text}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
