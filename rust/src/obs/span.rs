//! Lightweight phase spans: RAII guards that time a phase of work and
//! record it into the registry (histogram per phase) and, when a trace
//! sink is attached, as one Chrome event on the current thread's track.
//!
//! Tracks are thread-local (`set_track("worker-3")`); spans nest via a
//! thread-local depth counter, so `DYBW_LOG=trace` renders an indented
//! open/close mirror of the span stack without any trace file.

use std::cell::{Cell, RefCell};
use std::sync::Arc;
use std::time::Instant;

use super::Obs;

/// The phases of one training iteration, as the paper decomposes them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Waiting on the `n_i − b_i` fastest neighbours (the term DBW shrinks).
    Wait,
    /// Local gradient computation.
    Compute,
    /// Consensus mixing (eq. 6).
    Mix,
    /// Wire time: sends, receives, heartbeats.
    Comms,
    /// Test-loss evaluation.
    Eval,
    /// Checkpointing.
    Ckpt,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Wait => "wait",
            Phase::Compute => "compute",
            Phase::Mix => "mix",
            Phase::Comms => "comms",
            Phase::Eval => "eval",
            Phase::Ckpt => "ckpt",
        }
    }
}

thread_local! {
    static TRACK: RefCell<Arc<str>> = RefCell::new(Arc::from(""));
    static DEPTH: Cell<usize> = const { Cell::new(0) };
}

/// Name this thread's trace track (e.g. `worker-3`, `lane-0`,
/// `leader`). Spans opened on this thread land on that track.
pub fn set_track(name: &str) {
    TRACK.with(|t| *t.borrow_mut() = Arc::from(name));
}

fn track() -> Arc<str> {
    TRACK.with(|t| t.borrow().clone())
}

/// An open phase span; recording happens on drop.
pub struct Span {
    obs: Arc<Obs>,
    phase: Phase,
    start: Instant,
    start_us: u64,
    track: Arc<str>,
}

/// Open a span against the process-wide observer. Returns `None` (one
/// relaxed load, no allocation) when no observer is installed.
#[inline]
pub fn enter(phase: Phase) -> Option<Span> {
    if !super::enabled() {
        return None;
    }
    super::active().map(|obs| enter_with(&obs, phase))
}

/// Open a span against an explicit observer.
pub fn enter_with(obs: &Arc<Obs>, phase: Phase) -> Span {
    let track = track();
    let depth = DEPTH.with(|d| {
        let v = d.get();
        d.set(v + 1);
        v
    });
    crate::trace_!("obs", "{:indent$}open {} [{}]", "", phase.name(), track, indent = depth * 2);
    Span {
        obs: obs.clone(),
        phase,
        start: Instant::now(),
        start_us: obs.now_us(),
        track,
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let secs = self.start.elapsed().as_secs_f64();
        let depth = DEPTH.with(|d| {
            let v = d.get().saturating_sub(1);
            d.set(v);
            v
        });
        crate::trace_!(
            "obs",
            "{:indent$}close {} [{}] {:.3}ms",
            "",
            self.phase.name(),
            self.track,
            secs * 1e3,
            indent = depth * 2
        );
        self.obs
            .registry
            .histogram(&format!("span/{}_secs", self.phase.name()))
            .record_secs(secs);
        if let Some(sink) = self.obs.trace() {
            let dur_us = (secs * 1e6) as u64;
            sink.complete(&self.track, self.phase.name(), self.start_us, dur_us, &[]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn span_records_into_registry_histogram() {
        let obs = Obs::registry_only();
        set_track("worker-0");
        {
            let _outer = enter_with(&obs, Phase::Compute);
            let _inner = enter_with(&obs, Phase::Mix); // nests cleanly
        }
        let snap = obs.snapshot();
        for h in ["span/compute_secs", "span/mix_secs"] {
            let hist = snap.get("histograms").and_then(|v| v.get(h)).unwrap();
            assert_eq!(hist.get("count").and_then(Json::as_f64), Some(1.0), "{h}");
        }
        DEPTH.with(|d| assert_eq!(d.get(), 0, "span stack unwinds to empty"));
    }

    #[test]
    fn enter_without_observer_is_none() {
        // (another test may have installed a global observer; this only
        // checks the disabled fast path when nothing is installed)
        if !super::super::enabled() {
            assert!(enter(Phase::Wait).is_none());
        }
    }
}
