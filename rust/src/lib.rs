//! dybw — straggler-resilient consensus-based distributed training with
//! dynamic backup workers (reproduction of Xiong, Yan, Singh & Li, 2021).
//!
//! Three-layer architecture:
//! - **Layer 3 (this crate)** — the Rust coordinator: consensus graph,
//!   Metropolis mixing, straggler model, DTUR backup-worker selection,
//!   cb-DyBW / cb-Full / baseline training loops, metrics, benches.
//! - **Layer 2 (python/compile/model.py)** — JAX models (LRM, 2NN,
//!   tiny transformer) over flat parameter vectors, AOT-lowered to HLO
//!   text artifacts at build time.
//! - **Layer 1 (python/compile/kernels/)** — Pallas kernels (tiled
//!   matmul, fused bias+ReLU, fused softmax-xent) inside the Layer-2
//!   models.
//!
//! Python never runs at training time: the `runtime` module (behind the
//! optional `pjrt` cargo feature) loads the artifacts through the PJRT C
//! API (`xla` crate) and the coordinator drives them from Rust. The
//! default build has no external dependencies and uses the pure-Rust
//! native engines.
//!
//! Between the straggler substrate and the trainers sits [`des`], the
//! event-driven cluster simulator: asynchronous per-worker time on a
//! deterministic discrete-event core (timing-only at thousands of
//! workers, or full fidelity with real gradients).

// Style lints that fight this codebase's numerical idiom (parallel
// arrays indexed together, config structs mutated field-by-field after
// `Default::default()`, hand-rolled zero-dep JSON), kept allowed so CI
// can gate the correctness/suspicious/perf clippy groups with
// `-D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::field_reassign_with_default,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::len_without_is_empty,
    clippy::manual_range_contains,
    clippy::inherent_to_string
)]

pub mod comms;
pub mod consensus;
pub mod coordinator;
pub mod data;
pub mod des;
pub mod engine;
pub mod experiments;
pub mod graph;
pub mod metrics;
pub mod model;
pub mod obs;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod straggler;
pub mod util;
