//! Dense consensus-matrix analysis helpers.
//!
//! Tools behind the theory-facing tests and the `dybw analyze` command:
//! products Φ_{k:s} = P(s)···P(k) (eq. 8), deviation from the uniform
//! matrix (Lemma 2's geometric bound), and the spectral gap 1-λ₂ that
//! governs the consensus mixing rate.

use super::ConsensusMatrix;

pub type Dense = Vec<Vec<f64>>;

/// C = A · B (row-major dense).
pub fn matmul(a: &Dense, b: &Dense) -> Dense {
    let n = a.len();
    let m = b[0].len();
    let k = b.len();
    let mut c = vec![vec![0.0; m]; n];
    for i in 0..n {
        for l in 0..k {
            let av = a[i][l];
            if av == 0.0 {
                continue;
            }
            for j in 0..m {
                c[i][j] += av * b[l][j];
            }
        }
    }
    c
}

/// Φ over a sequence of consensus matrices (applied left-to-right).
pub fn product(mats: &[ConsensusMatrix]) -> Dense {
    assert!(!mats.is_empty());
    let mut acc = mats[0].to_dense();
    for m in &mats[1..] {
        acc = matmul(&acc, &m.to_dense());
    }
    acc
}

/// max_{i,j} |Φ_ij - 1/N| — Lemma 2's quantity.
pub fn uniform_deviation(phi: &Dense) -> f64 {
    let n = phi.len() as f64;
    phi.iter()
        .flatten()
        .map(|&v| (v - 1.0 / n).abs())
        .fold(0.0, f64::max)
}

/// Second-largest eigenvalue modulus of a doubly-stochastic symmetric P,
/// estimated by power iteration on the mean-deflated operator
/// x ↦ P(x - x̄·1). For symmetric P this is the mixing factor per round.
pub fn lambda2(p: &ConsensusMatrix, iters: usize) -> f64 {
    let d = p.to_dense();
    let n = d.len();
    let mut x: Vec<f64> = (0..n).map(|i| ((i * 2654435761) % 1000) as f64 / 1000.0 - 0.5).collect();
    deflate(&mut x);
    normalize(&mut x);
    let mut lam = 0.0;
    for _ in 0..iters {
        let mut y = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                y[i] += d[i][j] * x[j];
            }
        }
        deflate(&mut y);
        lam = norm(&y);
        if lam < 1e-300 {
            return 0.0;
        }
        for v in y.iter_mut() {
            *v /= lam;
        }
        x = y;
    }
    lam
}

fn deflate(x: &mut [f64]) {
    let mean = x.iter().sum::<f64>() / x.len() as f64;
    for v in x.iter_mut() {
        *v -= mean;
    }
}

fn norm(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

fn normalize(x: &mut [f64]) {
    let nn = norm(x);
    if nn > 0.0 {
        for v in x.iter_mut() {
            *v /= nn;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topology;
    use crate::util::rng::Rng;

    #[test]
    fn product_of_doubly_stochastic_is_doubly_stochastic() {
        let g = topology::random_connected(6, 0.5, &mut Rng::new(1));
        let mats: Vec<ConsensusMatrix> = (0..5)
            .map(|s| {
                let mut rng = Rng::new(s);
                let active: Vec<bool> = (0..6).map(|_| rng.uniform() < 0.7).collect();
                ConsensusMatrix::metropolis(&g, &active)
            })
            .collect();
        let phi = product(&mats);
        for row in &phi {
            let s: f64 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-10);
        }
        for j in 0..6 {
            let s: f64 = phi.iter().map(|r| r[j]).sum();
            assert!((s - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn phi_converges_to_uniform_geometrically() {
        // Lemma 1/2: |Φ_{k:1}(i,j) - 1/N| → 0 geometrically.
        let g = topology::random_connected(6, 0.5, &mut Rng::new(2));
        let p = ConsensusMatrix::metropolis_full(&g);
        let mut phi = p.to_dense();
        let mut prev = uniform_deviation(&phi);
        let mut shrank = 0;
        for _ in 0..100 {
            phi = matmul(&phi, &p.to_dense());
            let dev = uniform_deviation(&phi);
            if dev < prev {
                shrank += 1;
            }
            prev = dev;
        }
        assert!(prev < 1e-6, "deviation={prev}");
        assert!(shrank >= 90);
    }

    #[test]
    fn lambda2_bounds() {
        let g = topology::complete(8);
        let p = ConsensusMatrix::metropolis_full(&g);
        let l = lambda2(&p, 200);
        assert!(l < 0.2, "complete graph should mix almost instantly: {l}");

        let ring = topology::ring(16);
        let pr = ConsensusMatrix::metropolis_full(&ring);
        let lr = lambda2(&pr, 500);
        assert!(lr > 0.8 && lr < 1.0, "ring mixes slowly: {lr}");
    }

    #[test]
    fn lambda2_identity_is_one() {
        let p = ConsensusMatrix::identity(5);
        let l = lambda2(&p, 100);
        assert!((l - 1.0).abs() < 1e-9, "{l}");
    }

    #[test]
    fn spectral_gap_orders_standard_topologies() {
        // Mixing-rate sanity at fixed N=16: complete mixes in one round
        // (λ2 ≈ 0), the 4x4 grid/torus sits in between, and the ring is
        // slowest (λ2 = 1/3 + 2/3·cos(π/8) ≈ 0.95) — the connectivity
        // sensitivity behind Theorem 1's β^{NB} term.
        let n = 16;
        let l_ring = lambda2(&ConsensusMatrix::metropolis_full(&topology::ring(n)), 600);
        let l_grid = lambda2(&ConsensusMatrix::metropolis_full(&topology::grid(n)), 600);
        let l_full = lambda2(&ConsensusMatrix::metropolis_full(&topology::complete(n)), 600);
        assert!(l_full < 0.2, "complete should mix near-instantly: {l_full}");
        assert!(l_grid < l_ring, "grid {l_grid} should beat ring {l_ring}");
        assert!((0.8..1.0).contains(&l_ring), "ring λ2 out of range: {l_ring}");
    }
}
