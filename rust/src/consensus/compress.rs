//! Communication compression for the consensus exchange (extension).
//!
//! The paper's related work (Tang et al. [32], "Communication Compression
//! for Decentralized Training") motivates compressing what workers gossip.
//! Backup workers already cut the *number* of messages per round; this
//! module cuts their *size*, composing with cb-DyBW: workers exchange
//! compressed parameter *deltas* against the last broadcast state.
//!
//! Two standard operators, both with the contraction property
//! ‖C(x) − x‖ ≤ (1−δ)‖x‖ the compression literature requires:
//!
//! - [`TopK`]: keep the k largest-magnitude coordinates (sparsification).
//! - [`QuantizeBits`]: uniform b-bit stochastic-free quantisation of the
//!   value range (dense but narrow).
//!
//! Error feedback ([`ErrorFeedback`]) accumulates what compression
//! dropped and re-injects it next round — the standard fix that restores
//! convergence under aggressive compression.

/// A (lossy) vector compressor. Implementations must be contractions.
pub trait Compressor {
    /// Compress `x` into a wire representation.
    fn compress(&self, x: &[f32]) -> Compressed;
    /// Nominal wire size in bytes for a vector of length `n`.
    fn wire_bytes(&self, n: usize) -> usize;
    fn name(&self) -> String;
}

/// Wire format: either sparse pairs or dense quantised values.
#[derive(Debug, Clone)]
pub enum Compressed {
    Sparse { n: usize, idx: Vec<u32>, val: Vec<f32> },
    Quantized { n: usize, lo: f32, hi: f32, bits: u8, codes: Vec<u32> },
}

impl Compressed {
    /// Reconstruct the (lossy) dense vector.
    pub fn decompress(&self) -> Vec<f32> {
        match self {
            Compressed::Sparse { n, idx, val } => {
                let mut out = vec![0.0f32; *n];
                for (&i, &v) in idx.iter().zip(val) {
                    out[i as usize] = v;
                }
                out
            }
            Compressed::Quantized { n, lo, hi, bits, codes } => {
                let levels = (1u32 << bits) - 1;
                let scale = if levels == 0 { 0.0 } else { (hi - lo) / levels as f32 };
                let mut out = Vec::with_capacity(*n);
                for &c in codes {
                    out.push(lo + c as f32 * scale);
                }
                out
            }
        }
    }
}

/// Top-k magnitude sparsification.
#[derive(Debug, Clone, Copy)]
pub struct TopK {
    pub k: usize,
}

impl Compressor for TopK {
    fn compress(&self, x: &[f32]) -> Compressed {
        let k = self.k.min(x.len());
        // partial selection by magnitude
        let mut order: Vec<u32> = (0..x.len() as u32).collect();
        let nth = k.saturating_sub(1).min(order.len() - 1);
        order.select_nth_unstable_by(nth, |&a, &b| {
            x[b as usize]
                .abs()
                .partial_cmp(&x[a as usize].abs())
                .unwrap()
        });
        let mut idx: Vec<u32> = order[..k].to_vec();
        idx.sort_unstable();
        let val = idx.iter().map(|&i| x[i as usize]).collect();
        Compressed::Sparse { n: x.len(), idx, val }
    }

    fn wire_bytes(&self, n: usize) -> usize {
        self.k.min(n) * 8 // u32 idx + f32 val
    }

    fn name(&self) -> String {
        format!("top{}", self.k)
    }
}

/// Uniform b-bit range quantisation.
#[derive(Debug, Clone, Copy)]
pub struct QuantizeBits {
    pub bits: u8,
}

impl Compressor for QuantizeBits {
    fn compress(&self, x: &[f32]) -> Compressed {
        assert!(self.bits >= 1 && self.bits <= 16);
        let lo = x.iter().copied().fold(f32::INFINITY, f32::min);
        let hi = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let levels = (1u32 << self.bits) - 1;
        let inv = if hi > lo { levels as f32 / (hi - lo) } else { 0.0 };
        let codes = x
            .iter()
            .map(|&v| (((v - lo) * inv).round() as u32).min(levels))
            .collect();
        Compressed::Quantized {
            n: x.len(),
            lo,
            hi,
            bits: self.bits,
            codes,
        }
    }

    fn wire_bytes(&self, n: usize) -> usize {
        (n * self.bits as usize).div_ceil(8) + 8
    }

    fn name(&self) -> String {
        format!("q{}bit", self.bits)
    }
}

/// Error feedback accumulator (one per outgoing link or per worker).
#[derive(Debug, Clone)]
pub struct ErrorFeedback {
    residual: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new(dim: usize) -> Self {
        ErrorFeedback {
            residual: vec![0.0; dim],
        }
    }

    /// Compress `x + residual`, store what was lost, return the payload.
    pub fn step(&mut self, x: &[f32], comp: &dyn Compressor) -> Compressed {
        debug_assert_eq!(x.len(), self.residual.len());
        let corrected: Vec<f32> = x.iter().zip(&self.residual).map(|(a, r)| a + r).collect();
        let wire = comp.compress(&corrected);
        let recon = wire.decompress();
        for ((r, c), y) in self.residual.iter_mut().zip(&corrected).zip(&recon) {
            *r = c - y;
        }
        wire
    }

    pub fn residual_norm(&self) -> f64 {
        crate::util::vecmath::norm2(&self.residual)
    }

    pub fn residual(&self) -> &[f32] {
        &self.residual
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn topk_keeps_largest() {
        let x = vec![0.1, -5.0, 0.2, 3.0, -0.05];
        let c = TopK { k: 2 }.compress(&x);
        let d = c.decompress();
        assert_eq!(d, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn topk_contraction() {
        let x = randvec(500, 1);
        for k in [10, 100, 400] {
            let d = TopK { k }.compress(&x).decompress();
            let err: f32 = x.iter().zip(&d).map(|(a, b)| (a - b).powi(2)).sum();
            let norm: f32 = x.iter().map(|a| a * a).sum();
            assert!(err < norm, "k={k}: not a contraction");
        }
        // full k is lossless
        let d = TopK { k: 500 }.compress(&x).decompress();
        assert_eq!(d, x);
    }

    #[test]
    fn quantize_bounded_error() {
        let x = randvec(1000, 2);
        let lo = x.iter().copied().fold(f32::INFINITY, f32::min);
        let hi = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        for bits in [2u8, 4, 8, 12] {
            let d = QuantizeBits { bits }.compress(&x).decompress();
            let step = (hi - lo) / ((1u32 << bits) - 1) as f32;
            for (a, b) in x.iter().zip(&d) {
                assert!((a - b).abs() <= step * 0.5 + 1e-6, "bits={bits}");
            }
        }
    }

    #[test]
    fn quantize_wire_size_scales_with_bits() {
        let q4 = QuantizeBits { bits: 4 };
        let q8 = QuantizeBits { bits: 8 };
        assert!(q4.wire_bytes(1000) < q8.wire_bytes(1000));
        assert!(TopK { k: 10 }.wire_bytes(1000) < q4.wire_bytes(1000));
    }

    #[test]
    fn error_feedback_recovers_dropped_mass() {
        // Compressing a CONSTANT stream with error feedback: the running
        // sum of reconstructions must track the running sum of inputs.
        let x = randvec(200, 3);
        let comp = TopK { k: 20 };
        let mut ef = ErrorFeedback::new(200);
        let mut sum_recon = vec![0.0f32; 200];
        let rounds = 50;
        for _ in 0..rounds {
            let wire = ef.step(&x, &comp);
            for (s, v) in sum_recon.iter_mut().zip(wire.decompress()) {
                *s += v;
            }
        }
        // The EF invariant is exact: Σ_t recon_t + residual_T = T·x
        // (nothing is ever lost, only delayed).
        for (i, (&s, &xi)) in sum_recon.iter().zip(&x).enumerate() {
            let want = xi * rounds as f32;
            let got = s + ef.residual()[i];
            assert!(
                (got - want).abs() <= 1e-2 + want.abs() * 1e-4,
                "coord {i}: sum+residual {got} vs {want}"
            );
        }
        // and the delay (residual) stays bounded — it cannot exceed the
        // per-coordinate send-period bound Σ|x|/k · 1 plus slack
        let total_abs: f32 = x.iter().map(|v| v.abs()).sum();
        for (&r, &xi) in ef.residual().iter().zip(&x) {
            assert!(
                r.abs() <= total_abs / 20.0 + xi.abs() + 1.0,
                "residual {r} exceeds send-period bound"
            );
        }
    }

    #[test]
    fn quantize_constant_vector() {
        let x = vec![2.5f32; 64];
        let d = QuantizeBits { bits: 4 }.compress(&x).decompress();
        assert!(d.iter().all(|&v| (v - 2.5).abs() < 1e-6));
    }

    #[test]
    fn topk_zero_k_gives_zero_vector() {
        let x = randvec(10, 5);
        let d = TopK { k: 0 }.compress(&x).decompress();
        // k clamps to at least selecting per implementation; accept all-zero
        // or 1-element results but never more
        assert!(d.iter().filter(|&&v| v != 0.0).count() <= 1);
    }
}
