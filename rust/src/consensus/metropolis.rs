//! Metropolis weights on the time-varying active graph (Assumption 1).
//!
//! At iteration k only a subset of workers participates (those whose local
//! update beat the DTUR threshold); the active edge set is
//! `E_k = {(i,j) ∈ E : i and j both active}`. The Metropolis rule
//!
//! ```text
//! P_ij(k) = 1 / (1 + max(p_i(k), p_j(k)))   if (i,j) ∈ E_k
//! P_ii(k) = 1 - Σ_{j ∈ S_i(k)} P_ij(k)
//! P_ij(k) = 0                                otherwise
//! ```
//!
//! with `p_i(k) = |S_i(k)|` the active degree, yields a **doubly
//! stochastic, symmetric** matrix for every k — the property Theorems 1-2
//! lean on (products Φ_{k:s} stay doubly stochastic, Lemma 1). Workers
//! that miss the threshold get the identity row `P_ii = 1`: they keep
//! their local update and rejoin later (the backup-worker semantics).

use crate::graph::Graph;

/// Sparse row-major doubly-stochastic consensus matrix.
///
/// `rows[j]` lists `(i, P_ij)` over the *incoming* support of worker j —
/// exactly the worker set whose parameters j averages in eq. (6). By
/// symmetry of the Metropolis rule the same structure serves both row and
/// column views.
#[derive(Debug, Clone, PartialEq)]
pub struct ConsensusMatrix {
    pub n: usize,
    rows: Vec<Vec<(usize, f64)>>,
}

impl ConsensusMatrix {
    /// Identity (every worker keeps its own parameters).
    pub fn identity(n: usize) -> Self {
        ConsensusMatrix {
            n,
            rows: (0..n).map(|i| vec![(i, 1.0)]).collect(),
        }
    }

    /// Metropolis matrix for the given participation pattern.
    ///
    /// `active[v]` marks workers whose local update arrived within the
    /// iteration's threshold. Edges contribute only when both endpoints
    /// are active.
    pub fn metropolis(g: &Graph, active: &[bool]) -> Self {
        let n = g.n();
        assert_eq!(active.len(), n);
        // active degree p_i(k)
        let deg: Vec<usize> = (0..n)
            .map(|v| {
                if !active[v] {
                    0
                } else {
                    g.neighbors(v).filter(|&u| active[u]).count()
                }
            })
            .collect();
        let mut rows: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
        for j in 0..n {
            if !active[j] || deg[j] == 0 {
                rows[j].push((j, 1.0));
                continue;
            }
            let mut self_weight = 1.0;
            for i in g.neighbors(j).filter(|&u| active[u]) {
                let w = 1.0 / (1.0 + deg[i].max(deg[j]) as f64);
                rows[j].push((i, w));
                self_weight -= w;
            }
            rows[j].push((j, self_weight));
            debug_assert!(self_weight > -1e-12, "negative self weight at {j}");
        }
        for r in rows.iter_mut() {
            r.sort_unstable_by_key(|&(i, _)| i);
        }
        ConsensusMatrix { n, rows }
    }

    /// Full participation (cb-Full baseline): every worker active.
    pub fn metropolis_full(g: &Graph) -> Self {
        Self::metropolis(g, &vec![true; g.n()])
    }

    /// Incoming support of worker j: the S_j(k) ∪ {j} it averages over.
    pub fn row(&self, j: usize) -> &[(usize, f64)] {
        &self.rows[j]
    }

    /// β(k): smallest strictly positive entry (paper's β, per-matrix).
    pub fn min_positive(&self) -> f64 {
        self.rows
            .iter()
            .flatten()
            .map(|&(_, w)| w)
            .filter(|&w| w > 1e-15)
            .fold(f64::INFINITY, f64::min)
    }

    /// Dense copy (analysis/tests only).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut m = vec![vec![0.0; self.n]; self.n];
        for (j, row) in self.rows.iter().enumerate() {
            for &(i, w) in row {
                m[j][i] = w;
            }
        }
        m
    }

    /// Verify double stochasticity + non-negativity to `tol`.
    pub fn check_doubly_stochastic(&self, tol: f64) -> Result<(), String> {
        let mut col = vec![0.0f64; self.n];
        for (j, row) in self.rows.iter().enumerate() {
            let mut s = 0.0;
            for &(i, w) in row {
                if w < -tol {
                    return Err(format!("negative weight P[{j}][{i}] = {w}"));
                }
                s += w;
                col[i] += w;
            }
            if (s - 1.0).abs() > tol {
                return Err(format!("row {j} sums to {s}"));
            }
        }
        for (i, &c) in col.iter().enumerate() {
            if (c - 1.0).abs() > tol {
                return Err(format!("col {i} sums to {c}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topology;
    use crate::util::rng::Rng;

    #[test]
    fn full_participation_doubly_stochastic() {
        for seed in 0..10 {
            let g = topology::random_connected(8, 0.4, &mut Rng::new(seed));
            let p = ConsensusMatrix::metropolis_full(&g);
            p.check_doubly_stochastic(1e-12).unwrap();
        }
    }

    #[test]
    fn partial_participation_doubly_stochastic() {
        let mut rng = Rng::new(3);
        for seed in 0..20 {
            let g = topology::random_connected(10, 0.35, &mut Rng::new(seed));
            let active: Vec<bool> = (0..10).map(|_| rng.uniform() < 0.6).collect();
            let p = ConsensusMatrix::metropolis(&g, &active);
            p.check_doubly_stochastic(1e-12).unwrap();
        }
    }

    #[test]
    fn inactive_worker_keeps_identity_row() {
        let g = topology::complete(4);
        let active = vec![true, false, true, true];
        let p = ConsensusMatrix::metropolis(&g, &active);
        assert_eq!(p.row(1), &[(1, 1.0)]);
        // and nobody averages from worker 1
        for j in [0usize, 2, 3] {
            assert!(p.row(j).iter().all(|&(i, _)| i != 1));
        }
    }

    #[test]
    fn symmetric_weights() {
        let g = topology::random_connected(9, 0.4, &mut Rng::new(7));
        let p = ConsensusMatrix::metropolis_full(&g);
        let d = p.to_dense();
        for a in 0..9 {
            for b in 0..9 {
                assert!((d[a][b] - d[b][a]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn matches_hand_computed_triangle() {
        // Triangle graph, all active: deg = 2 everywhere,
        // off-diagonal = 1/3, diagonal = 1/3.
        let g = Graph::from_edges(3, &[(0, 1), (1, 2), (0, 2)]);
        let p = ConsensusMatrix::metropolis_full(&g);
        let d = p.to_dense();
        for a in 0..3 {
            for b in 0..3 {
                assert!((d[a][b] - 1.0 / 3.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn min_positive_of_identity_is_one() {
        assert_eq!(ConsensusMatrix::identity(5).min_positive(), 1.0);
    }

    #[test]
    fn all_inactive_gives_identity() {
        let g = topology::ring(6);
        let p = ConsensusMatrix::metropolis(&g, &vec![false; 6]);
        assert_eq!(p, ConsensusMatrix::identity(6));
    }

    #[test]
    fn rows_sum_to_one_on_standard_topologies() {
        for g in [
            topology::ring(8),
            topology::grid(9),
            topology::complete(7),
            topology::star(6),
        ] {
            let p = ConsensusMatrix::metropolis_full(&g);
            p.check_doubly_stochastic(1e-12).unwrap();
            for j in 0..g.n() {
                let s: f64 = p.row(j).iter().map(|&(_, w)| w).sum();
                assert!((s - 1.0).abs() < 1e-12, "row {j} sums to {s}");
            }
        }
    }

    #[test]
    fn symmetric_on_standard_topologies_under_partial_participation() {
        let mut rng = Rng::new(31);
        for g in [topology::ring(10), topology::grid(12), topology::complete(6)] {
            let n = g.n();
            for _ in 0..8 {
                let active: Vec<bool> = (0..n).map(|_| rng.uniform() < 0.7).collect();
                let p = ConsensusMatrix::metropolis(&g, &active);
                p.check_doubly_stochastic(1e-12).unwrap();
                let d = p.to_dense();
                for a in 0..n {
                    for b in 0..n {
                        assert!(
                            (d[a][b] - d[b][a]).abs() < 1e-12,
                            "P[{a}][{b}] != P[{b}][{a}]"
                        );
                    }
                }
            }
        }
    }
}
