//! Consensus-matrix substrate (paper Assumption 1 + eq. (6)).
//!
//! - [`metropolis`] — non-negative Metropolis weight rule on the
//!   time-varying active graph; guarantees every `P(k)` doubly stochastic.
//! - [`mixing`] — the eq. (6) parameter-averaging step over flat vectors.
//! - [`matrix`] — dense matrix helpers: products Φ_{k:s}, uniform-limit
//!   deviation (Lemma 2), spectral gap — used by analysis tools + tests.

pub mod compress;
pub mod matrix;
pub mod metropolis;
pub mod mixing;

pub use metropolis::ConsensusMatrix;
