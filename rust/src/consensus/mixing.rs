//! The eq. (6) consensus update over flat parameter vectors.
//!
//! Given the locally-updated parameters w̃_i(k) (eq. 5) of all workers and
//! the iteration's consensus matrix P(k), compute
//!
//! ```text
//! w_j(k) = Σ_{i ∈ S_j(k) ∪ {j}} P_ij(k) · w̃_i(k)
//! ```
//!
//! for every j. This is the Layer-3 hot path; it uses the blocked
//! `weighted_sum_into` kernel and a double-buffer scheme so no parameter
//! vector is ever reallocated.

use super::ConsensusMatrix;
use crate::util::vecmath;

/// Double-buffered parameter store for N workers × P params.
///
/// `front` holds w(k), `back` is scratch for w(k+1); `mix` writes into
/// `back` and swaps. Buffers are allocated once at construction.
#[derive(Debug, Clone)]
pub struct ParamBuffers {
    n: usize,
    dim: usize,
    front: Vec<Vec<f32>>,
    back: Vec<Vec<f32>>,
}

impl ParamBuffers {
    pub fn new(n: usize, dim: usize) -> Self {
        ParamBuffers {
            n,
            dim,
            front: vec![vec![0.0; dim]; n],
            back: vec![vec![0.0; dim]; n],
        }
    }

    pub fn from_initial(init: Vec<Vec<f32>>) -> Self {
        let n = init.len();
        let dim = init[0].len();
        assert!(init.iter().all(|v| v.len() == dim));
        ParamBuffers {
            n,
            dim,
            back: vec![vec![0.0; dim]; n],
            front: init,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn get(&self, j: usize) -> &[f32] {
        &self.front[j]
    }

    pub fn get_mut(&mut self, j: usize) -> &mut [f32] {
        &mut self.front[j]
    }

    /// Apply one consensus round: front := P(k)ᵀ · front (row view of
    /// eq. 6), using the back buffer as scratch. O(Σ_j |S_j| · P) flops.
    pub fn mix(&mut self, p: &ConsensusMatrix) {
        assert_eq!(p.n, self.n);
        for j in 0..self.n {
            let row = p.row(j);
            // Gather sources from `front`, write into `back[j]`.
            let coeffs: Vec<f32> = row.iter().map(|&(_, w)| w as f32).collect();
            let srcs: Vec<&[f32]> = row.iter().map(|&(i, _)| self.front[i].as_slice()).collect();
            vecmath::weighted_sum_into(&mut self.back[j], &srcs, &coeffs);
        }
        std::mem::swap(&mut self.front, &mut self.back);
    }

    /// Compressed consensus round (extension; see consensus::compress):
    /// every worker broadcasts a lossy encoding of its parameters (with
    /// per-worker error feedback), neighbours mix the *reconstructions*.
    /// Returns the total wire bytes this round would have cost.
    pub fn mix_compressed(
        &mut self,
        p: &ConsensusMatrix,
        comp: &dyn super::compress::Compressor,
        efs: &mut [super::compress::ErrorFeedback],
    ) -> usize {
        assert_eq!(p.n, self.n);
        assert_eq!(efs.len(), self.n);
        // Each worker publishes one compressed broadcast per round.
        let recon: Vec<Vec<f32>> = (0..self.n)
            .map(|i| efs[i].step(&self.front[i], comp).decompress())
            .collect();
        let mut wire = 0usize;
        for j in 0..self.n {
            let row = p.row(j);
            let coeffs: Vec<f32> = row.iter().map(|&(_, w)| w as f32).collect();
            // worker j uses its OWN exact params, neighbours' reconstructions
            let srcs: Vec<&[f32]> = row
                .iter()
                .map(|&(i, _)| {
                    if i == j {
                        self.front[i].as_slice()
                    } else {
                        wire += comp.wire_bytes(self.dim);
                        recon[i].as_slice()
                    }
                })
                .collect();
            vecmath::weighted_sum_into(&mut self.back[j], &srcs, &coeffs);
        }
        std::mem::swap(&mut self.front, &mut self.back);
        wire
    }

    /// Network average ȳ(k) = (1/N) Σ_j w_j(k).
    pub fn average(&self) -> Vec<f32> {
        let srcs: Vec<&[f32]> = self.front.iter().map(|v| v.as_slice()).collect();
        vecmath::mean_of(&srcs)
    }

    /// Max pairwise disagreement max_j ||w_j - ȳ||₂ — the consensus error
    /// tracked by Corollary 1 tests.
    pub fn consensus_error(&self) -> f64 {
        let avg = self.average();
        (0..self.n)
            .map(|j| vecmath::dist(&self.front[j], &avg))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::ConsensusMatrix;
    use crate::graph::topology;
    use crate::util::rng::Rng;

    fn randomized(n: usize, dim: usize, seed: u64) -> ParamBuffers {
        let mut rng = Rng::new(seed);
        ParamBuffers::from_initial(
            (0..n)
                .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
                .collect(),
        )
    }

    #[test]
    fn identity_mix_is_noop() {
        let mut b = randomized(4, 64, 0);
        let before: Vec<Vec<f32>> = (0..4).map(|j| b.get(j).to_vec()).collect();
        b.mix(&ConsensusMatrix::identity(4));
        for j in 0..4 {
            assert_eq!(b.get(j), before[j].as_slice());
        }
    }

    #[test]
    fn mixing_preserves_network_average() {
        // Doubly stochastic P ⇒ the network average is invariant — the
        // core conservation property behind eq. (8) / Theorem 2.
        let g = topology::random_connected(7, 0.4, &mut Rng::new(5));
        let p = ConsensusMatrix::metropolis_full(&g);
        let mut b = randomized(7, 128, 1);
        let avg0 = b.average();
        for _ in 0..10 {
            b.mix(&p);
        }
        let avg1 = b.average();
        for (a, c) in avg0.iter().zip(&avg1) {
            assert!((a - c).abs() < 1e-4, "{a} vs {c}");
        }
    }

    #[test]
    fn repeated_mixing_reaches_consensus() {
        // Corollary 1: W(k) → y·1ᵀ. On a connected graph with full
        // participation the consensus error must decay geometrically.
        let g = topology::random_connected(6, 0.5, &mut Rng::new(9));
        let p = ConsensusMatrix::metropolis_full(&g);
        let mut b = randomized(6, 32, 2);
        let e0 = b.consensus_error();
        for _ in 0..200 {
            b.mix(&p);
        }
        let e1 = b.consensus_error();
        assert!(e1 < e0 * 1e-3, "e0={e0} e1={e1}");
    }

    #[test]
    fn partial_participation_still_preserves_average() {
        let g = topology::random_connected(8, 0.4, &mut Rng::new(11));
        let mut rng = Rng::new(13);
        let mut b = randomized(8, 64, 3);
        let avg0 = b.average();
        for _ in 0..25 {
            let active: Vec<bool> = (0..8).map(|_| rng.uniform() < 0.5).collect();
            b.mix(&ConsensusMatrix::metropolis(&g, &active));
        }
        let avg1 = b.average();
        for (a, c) in avg0.iter().zip(&avg1) {
            assert!((a - c).abs() < 1e-4);
        }
    }

    #[test]
    fn consensus_error_zero_when_equal() {
        let b = ParamBuffers::from_initial(vec![vec![1.5; 10]; 5]);
        assert_eq!(b.consensus_error(), 0.0);
    }

    #[test]
    fn denser_topologies_contract_disagreement_faster() {
        // Same initial disagreement, same number of rounds: the complete
        // graph averages exactly in one round, the grid/torus beats the
        // ring — consistent with the λ2 ordering asserted in matrix.rs.
        let n = 16;
        let rounds = 30;
        let err_after = |g: &crate::graph::Graph| {
            let p = ConsensusMatrix::metropolis_full(g);
            let mut b = randomized(n, 64, 77);
            for _ in 0..rounds {
                b.mix(&p);
            }
            b.consensus_error()
        };
        let e_ring = err_after(&topology::ring(n));
        let e_grid = err_after(&topology::grid(n));
        let e_full = err_after(&topology::complete(n));
        assert!(e_full < 1e-4, "complete graph should reach consensus: {e_full}");
        assert!(e_grid < e_ring, "grid {e_grid} should beat ring {e_ring}");
    }

    #[test]
    fn compressed_mixing_still_contracts() {
        use crate::consensus::compress::{ErrorFeedback, TopK};
        let g = topology::random_connected(6, 0.5, &mut Rng::new(21));
        let p = ConsensusMatrix::metropolis_full(&g);
        let dim = 256;
        let mut b = randomized(6, dim, 22);
        let comp = TopK { k: dim / 4 };
        let mut efs: Vec<ErrorFeedback> =
            (0..6).map(|_| ErrorFeedback::new(dim)).collect();
        let e0 = b.consensus_error();
        let mut wire = 0;
        for _ in 0..120 {
            wire += b.mix_compressed(&p, &comp, &mut efs);
        }
        let e1 = b.consensus_error();
        // Error feedback leaves a noise floor (exact consensus needs the
        // CHOCO-style diminishing mixing step); assert real contraction.
        assert!(e1 < e0 * 0.25, "compressed gossip failed to contract: {e0} -> {e1}");
        // wire accounting: every round, every worker pulls |S_j| compressed
        // neighbour payloads
        assert!(wire > 0);
        // 4x sparsification (idx+val = 8 B/coord) halves the dense
        // f32 broadcast cost
        let dense_round: usize = (0..6)
            .map(|j| (p.row(j).len() - 1) * dim * 4)
            .sum();
        assert!(
            2 * wire <= 120 * dense_round,
            "wire {wire} not cheaper than dense {}",
            120 * dense_round
        );
    }
}
