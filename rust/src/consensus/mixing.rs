//! The eq. (6) consensus update over flat parameter vectors.
//!
//! Given the locally-updated parameters w̃_i(k) (eq. 5) of all workers and
//! the iteration's consensus matrix P(k), compute
//!
//! ```text
//! w_j(k) = Σ_{i ∈ S_j(k) ∪ {j}} P_ij(k) · w̃_i(k)
//! ```
//!
//! for every j. This is the Layer-3 hot path; it uses the blocked
//! `weighted_sum_into` kernel and a double-buffer scheme so no parameter
//! vector is ever reallocated.
//!
//! Each row j writes only the disjoint `back[j]`, so the update is
//! embarrassingly parallel across workers: the `*_pooled` variants fan
//! the per-worker weighted row-sums over an
//! [`EnginePool`](crate::engine::EnginePool)'s lanes and are
//! **bit-identical** to the sequential loops they shadow (same kernel,
//! same per-row operand order; only the scheduling changes).

use super::ConsensusMatrix;
use crate::engine::EnginePool;
use crate::util::vecmath;

/// One eq. (6) row-sum: gather row j's Metropolis coefficients and source
/// slices (via `src_of`) and run the shared `weighted_sum_into` kernel
/// into `out`. EVERY mixing variant — sequential and pooled, exact and
/// compressed — goes through this single function, which is what makes
/// the documented bit-identity across variants a structural property
/// rather than four copies that must be kept in sync by hand.
fn row_sum_into<'a, F>(row: &[(usize, f64)], src_of: F, out: &mut [f32])
where
    F: Fn(usize) -> &'a [f32],
{
    let mut coeffs: Vec<f32> = Vec::with_capacity(row.len());
    let mut srcs: Vec<&[f32]> = Vec::with_capacity(row.len());
    for &(i, w) in row {
        coeffs.push(w as f32);
        srcs.push(src_of(i));
    }
    vecmath::weighted_sum_into(out, &srcs, &coeffs);
}

/// Double-buffered parameter store for N workers × P params.
///
/// `front` holds w(k), `back` is scratch for w(k+1); `mix` writes into
/// `back` and swaps. Buffers are allocated once at construction.
#[derive(Debug, Clone)]
pub struct ParamBuffers {
    n: usize,
    dim: usize,
    front: Vec<Vec<f32>>,
    back: Vec<Vec<f32>>,
}

impl ParamBuffers {
    pub fn new(n: usize, dim: usize) -> Self {
        ParamBuffers {
            n,
            dim,
            front: vec![vec![0.0; dim]; n],
            back: vec![vec![0.0; dim]; n],
        }
    }

    pub fn from_initial(init: Vec<Vec<f32>>) -> Self {
        let n = init.len();
        let dim = init[0].len();
        assert!(init.iter().all(|v| v.len() == dim));
        ParamBuffers {
            n,
            dim,
            back: vec![vec![0.0; dim]; n],
            front: init,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn get(&self, j: usize) -> &[f32] {
        &self.front[j]
    }

    pub fn get_mut(&mut self, j: usize) -> &mut [f32] {
        &mut self.front[j]
    }

    /// Apply one consensus round: front := P(k)ᵀ · front (row view of
    /// eq. 6), using the back buffer as scratch. O(Σ_j |S_j| · P) flops.
    pub fn mix(&mut self, p: &ConsensusMatrix) {
        assert_eq!(p.n, self.n);
        let front = &self.front;
        for (j, back_j) in self.back.iter_mut().enumerate() {
            // Gather sources from `front`, write into `back[j]`.
            row_sum_into(p.row(j), |i| front[i].as_slice(), back_j);
        }
        std::mem::swap(&mut self.front, &mut self.back);
    }

    /// Compressed consensus round (extension; see consensus::compress):
    /// every worker broadcasts a lossy encoding of its parameters (with
    /// per-worker error feedback), neighbours mix the *reconstructions*.
    /// Returns the total wire bytes this round would have cost.
    pub fn mix_compressed(
        &mut self,
        p: &ConsensusMatrix,
        comp: &dyn super::compress::Compressor,
        efs: &mut [super::compress::ErrorFeedback],
    ) -> usize {
        assert_eq!(p.n, self.n);
        assert_eq!(efs.len(), self.n);
        // Each worker publishes one compressed broadcast per round.
        let recon: Vec<Vec<f32>> = (0..self.n)
            .map(|i| efs[i].step(&self.front[i], comp).decompress())
            .collect();
        let wire = self.wire_cost(p, comp);
        let front = &self.front;
        for (j, back_j) in self.back.iter_mut().enumerate() {
            // worker j uses its OWN exact params, neighbours' reconstructions
            let src_of = |i: usize| {
                if i == j {
                    front[i].as_slice()
                } else {
                    recon[i].as_slice()
                }
            };
            row_sum_into(p.row(j), src_of, back_j);
        }
        std::mem::swap(&mut self.front, &mut self.back);
        wire
    }

    /// Wire bytes one compressed round costs: every neighbour payload
    /// worker j pulls (row support minus itself) is one compressed
    /// broadcast. Pure arithmetic over the row structure, shared by the
    /// sequential and pooled compressed paths.
    fn wire_cost(&self, p: &ConsensusMatrix, comp: &dyn super::compress::Compressor) -> usize {
        let mut wire = 0usize;
        for j in 0..self.n {
            let pulls = p.row(j).iter().filter(|&&(i, _)| i != j).count();
            wire += pulls * comp.wire_bytes(self.dim);
        }
        wire
    }

    /// Parallel eq. (6): identical arithmetic to [`mix`](Self::mix), with
    /// the per-worker weighted row-sums fanned over the pool's lanes as
    /// borrowed-closure tasks. Row j reads `front` (shared) and writes
    /// only the disjoint `back[j]`, so the fan-out is race-free and the
    /// result is bit-identical to the sequential path regardless of lane
    /// count or which lane runs which row.
    pub fn mix_pooled(&mut self, p: &ConsensusMatrix, pool: &EnginePool) -> anyhow::Result<()> {
        assert_eq!(p.n, self.n);
        if pool.threads() <= 1 {
            self.mix(p);
            return Ok(());
        }
        let front = &self.front;
        let mut tasks: Vec<_> = self
            .back
            .iter_mut()
            .enumerate()
            .map(|(j, back_j)| {
                let row = p.row(j);
                move || -> anyhow::Result<()> {
                    row_sum_into(row, |i| front[i].as_slice(), back_j);
                    Ok(())
                }
            })
            .collect();
        pool.run_tasks(&mut tasks)?;
        drop(tasks);
        std::mem::swap(&mut self.front, &mut self.back);
        Ok(())
    }

    /// Parallel compressed consensus round: bit-identical to
    /// [`mix_compressed`](Self::mix_compressed), in two pooled phases.
    /// Phase 1 runs every worker's compress→error-feedback→reconstruct
    /// step (worker-local state, so per-worker independent); phase 2 runs
    /// the weighted row-sums exactly as [`mix_pooled`](Self::mix_pooled).
    /// Wire accounting is pure arithmetic over the row structure and is
    /// summed on the caller thread, so the parallel rows never share a
    /// counter.
    pub fn mix_compressed_pooled(
        &mut self,
        p: &ConsensusMatrix,
        comp: &(dyn super::compress::Compressor + Sync),
        efs: &mut [super::compress::ErrorFeedback],
        pool: &EnginePool,
    ) -> anyhow::Result<usize> {
        assert_eq!(p.n, self.n);
        assert_eq!(efs.len(), self.n);
        if pool.threads() <= 1 {
            return Ok(self.mix_compressed(p, comp, efs));
        }
        // Phase 1: every worker publishes one compressed broadcast and
        // the network reconstructs it (per-worker: touches only efs[i]).
        let mut recon: Vec<Vec<f32>> = (0..self.n).map(|_| Vec::new()).collect();
        {
            let mut tasks: Vec<_> = recon
                .iter_mut()
                .zip(efs.iter_mut())
                .zip(self.front.iter())
                .map(|((slot, ef), w)| {
                    move || -> anyhow::Result<()> {
                        *slot = ef.step(w, comp).decompress();
                        Ok(())
                    }
                })
                .collect();
            pool.run_tasks(&mut tasks)?;
        }
        let wire = self.wire_cost(p, comp);
        // Phase 2: the row sums — worker j uses its OWN exact params,
        // neighbours' reconstructions.
        {
            let front = &self.front;
            let recon = &recon;
            let mut tasks: Vec<_> = self
                .back
                .iter_mut()
                .enumerate()
                .map(|(j, back_j)| {
                    let row = p.row(j);
                    move || -> anyhow::Result<()> {
                        let src_of = |i: usize| {
                            if i == j {
                                front[i].as_slice()
                            } else {
                                recon[i].as_slice()
                            }
                        };
                        row_sum_into(row, src_of, back_j);
                        Ok(())
                    }
                })
                .collect();
            pool.run_tasks(&mut tasks)?;
        }
        std::mem::swap(&mut self.front, &mut self.back);
        Ok(wire)
    }

    /// Network average ȳ(k) = (1/N) Σ_j w_j(k).
    pub fn average(&self) -> Vec<f32> {
        let srcs: Vec<&[f32]> = self.front.iter().map(|v| v.as_slice()).collect();
        vecmath::mean_of(&srcs)
    }

    /// Max pairwise disagreement max_j ||w_j - ȳ||₂ — the consensus error
    /// tracked by Corollary 1 tests.
    pub fn consensus_error(&self) -> f64 {
        let avg = self.average();
        (0..self.n)
            .map(|j| vecmath::dist(&self.front[j], &avg))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::ConsensusMatrix;
    use crate::graph::topology;
    use crate::util::rng::Rng;

    fn randomized(n: usize, dim: usize, seed: u64) -> ParamBuffers {
        let mut rng = Rng::new(seed);
        ParamBuffers::from_initial(
            (0..n)
                .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
                .collect(),
        )
    }

    #[test]
    fn identity_mix_is_noop() {
        let mut b = randomized(4, 64, 0);
        let before: Vec<Vec<f32>> = (0..4).map(|j| b.get(j).to_vec()).collect();
        b.mix(&ConsensusMatrix::identity(4));
        for j in 0..4 {
            assert_eq!(b.get(j), before[j].as_slice());
        }
    }

    #[test]
    fn mixing_preserves_network_average() {
        // Doubly stochastic P ⇒ the network average is invariant — the
        // core conservation property behind eq. (8) / Theorem 2.
        let g = topology::random_connected(7, 0.4, &mut Rng::new(5));
        let p = ConsensusMatrix::metropolis_full(&g);
        let mut b = randomized(7, 128, 1);
        let avg0 = b.average();
        for _ in 0..10 {
            b.mix(&p);
        }
        let avg1 = b.average();
        for (a, c) in avg0.iter().zip(&avg1) {
            assert!((a - c).abs() < 1e-4, "{a} vs {c}");
        }
    }

    #[test]
    fn repeated_mixing_reaches_consensus() {
        // Corollary 1: W(k) → y·1ᵀ. On a connected graph with full
        // participation the consensus error must decay geometrically.
        let g = topology::random_connected(6, 0.5, &mut Rng::new(9));
        let p = ConsensusMatrix::metropolis_full(&g);
        let mut b = randomized(6, 32, 2);
        let e0 = b.consensus_error();
        for _ in 0..200 {
            b.mix(&p);
        }
        let e1 = b.consensus_error();
        assert!(e1 < e0 * 1e-3, "e0={e0} e1={e1}");
    }

    #[test]
    fn partial_participation_still_preserves_average() {
        let g = topology::random_connected(8, 0.4, &mut Rng::new(11));
        let mut rng = Rng::new(13);
        let mut b = randomized(8, 64, 3);
        let avg0 = b.average();
        for _ in 0..25 {
            let active: Vec<bool> = (0..8).map(|_| rng.uniform() < 0.5).collect();
            b.mix(&ConsensusMatrix::metropolis(&g, &active));
        }
        let avg1 = b.average();
        for (a, c) in avg0.iter().zip(&avg1) {
            assert!((a - c).abs() < 1e-4);
        }
    }

    #[test]
    fn consensus_error_zero_when_equal() {
        let b = ParamBuffers::from_initial(vec![vec![1.5; 10]; 5]);
        assert_eq!(b.consensus_error(), 0.0);
    }

    #[test]
    fn denser_topologies_contract_disagreement_faster() {
        // Same initial disagreement, same number of rounds: the complete
        // graph averages exactly in one round, the grid/torus beats the
        // ring — consistent with the λ2 ordering asserted in matrix.rs.
        let n = 16;
        let rounds = 30;
        let err_after = |g: &crate::graph::Graph| {
            let p = ConsensusMatrix::metropolis_full(g);
            let mut b = randomized(n, 64, 77);
            for _ in 0..rounds {
                b.mix(&p);
            }
            b.consensus_error()
        };
        let e_ring = err_after(&topology::ring(n));
        let e_grid = err_after(&topology::grid(n));
        let e_full = err_after(&topology::complete(n));
        assert!(e_full < 1e-4, "complete graph should reach consensus: {e_full}");
        assert!(e_grid < e_ring, "grid {e_grid} should beat ring {e_ring}");
    }

    fn tiny_pool(threads: usize) -> EnginePool {
        EnginePool::tasks_only(threads).unwrap()
    }

    fn assert_rows_bits_eq(a: &ParamBuffers, b: &ParamBuffers, ctx: &str) {
        assert_eq!(a.n(), b.n());
        for j in 0..a.n() {
            for (k, (x, y)) in a.get(j).iter().zip(b.get(j)).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: row {j} coord {k}");
            }
        }
    }

    /// Tentpole invariant: the pooled mixing fan-out is bit-identical to
    /// the sequential loop, across full and partial participation and
    /// across pool sizes (including the 1-lane fallback).
    #[test]
    fn pooled_mix_bit_identical_to_sequential() {
        let n = 8;
        let dim = 2048;
        let g = topology::random_connected(n, 0.4, &mut Rng::new(33));
        for threads in [1usize, 3] {
            let pool = tiny_pool(threads);
            let mut seq = randomized(n, dim, 44);
            let mut par = randomized(n, dim, 44);
            let mut rng = Rng::new(55);
            for round in 0..12 {
                let active: Vec<bool> = (0..n).map(|_| rng.uniform() < 0.7).collect();
                let p = ConsensusMatrix::metropolis(&g, &active);
                seq.mix(&p);
                par.mix_pooled(&p, &pool).unwrap();
                assert_rows_bits_eq(&seq, &par, &format!("t{threads} round {round}"));
            }
        }
    }

    /// Same invariant on the compressed path: reconstruction, row sums,
    /// and the wire-byte count must all match the sequential loop.
    #[test]
    fn pooled_compressed_mix_bit_identical_to_sequential() {
        use crate::consensus::compress::{ErrorFeedback, TopK};
        let n = 6;
        let dim = 1024;
        let g = topology::random_connected(n, 0.5, &mut Rng::new(66));
        let comp = TopK { k: dim / 4 };
        for threads in [1usize, 4] {
            let pool = tiny_pool(threads);
            let mut seq = randomized(n, dim, 77);
            let mut par = randomized(n, dim, 77);
            let mut efs_seq = vec![ErrorFeedback::new(dim); n];
            let mut efs_par = vec![ErrorFeedback::new(dim); n];
            let mut rng = Rng::new(88);
            for round in 0..8 {
                let active: Vec<bool> = (0..n).map(|_| rng.uniform() < 0.8).collect();
                let p = ConsensusMatrix::metropolis(&g, &active);
                let w_seq = seq.mix_compressed(&p, &comp, &mut efs_seq);
                let w_par = par
                    .mix_compressed_pooled(&p, &comp, &mut efs_par, &pool)
                    .unwrap();
                assert_eq!(w_seq, w_par, "t{threads} round {round}: wire bytes differ");
                assert_rows_bits_eq(&seq, &par, &format!("t{threads} round {round}"));
                // error-feedback residuals are part of the recurrence —
                // they must track bit-for-bit too
                for (j, (a, b)) in efs_seq.iter().zip(&efs_par).enumerate() {
                    for (x, y) in a.residual().iter().zip(b.residual()) {
                        assert_eq!(x.to_bits(), y.to_bits(), "residual {j} diverged");
                    }
                }
            }
        }
    }

    #[test]
    fn compressed_mixing_still_contracts() {
        use crate::consensus::compress::{ErrorFeedback, TopK};
        let g = topology::random_connected(6, 0.5, &mut Rng::new(21));
        let p = ConsensusMatrix::metropolis_full(&g);
        let dim = 256;
        let mut b = randomized(6, dim, 22);
        let comp = TopK { k: dim / 4 };
        let mut efs: Vec<ErrorFeedback> = vec![ErrorFeedback::new(dim); 6];
        let e0 = b.consensus_error();
        let mut wire = 0;
        for _ in 0..120 {
            wire += b.mix_compressed(&p, &comp, &mut efs);
        }
        let e1 = b.consensus_error();
        // Error feedback leaves a noise floor (exact consensus needs the
        // CHOCO-style diminishing mixing step); assert real contraction.
        assert!(e1 < e0 * 0.25, "compressed gossip failed to contract: {e0} -> {e1}");
        // wire accounting: every round, every worker pulls |S_j| compressed
        // neighbour payloads
        assert!(wire > 0);
        // 4x sparsification (idx+val = 8 B/coord) halves the dense
        // f32 broadcast cost
        let dense_round: usize = (0..6)
            .map(|j| (p.row(j).len() - 1) * dim * 4)
            .sum();
        assert!(
            2 * wire <= 120 * dense_round,
            "wire {wire} not cheaper than dense {}",
            120 * dense_round
        );
    }
}
