//! `figure async` — the asynchronous-timeline results.
//!
//! Two panels:
//!
//! 1. **Scale (timing-only DES)**: a 1000+-worker ring swept over wait
//!    policies on one identical trace, plus an N-sweep showing cb-DyBW's
//!    per-worker pace stays flat as the cluster grows while the full
//!    barrier's pace degrades — the asynchronous face of §5's linear
//!    speedup, at sizes the lockstep driver cannot touch.
//! 2. **Time-vs-loss (full-fidelity DES)**: real gradients on the
//!    asynchronous schedule, cb-DyBW vs the full barrier on the same
//!    recorded realisation — Fig. 5/7's story with per-worker clocks.

use std::path::Path;

use crate::coordinator::setup::Setup;
use crate::des::{ClusterSim, ComputeTimes, NoHooks, Scenario, WaitPolicy};
use crate::graph::topology;
use crate::metrics::export;
use crate::metrics::RunHistory;
use crate::straggler::link::LinkModel;
use crate::straggler::trace::Trace;
use crate::straggler::Dist;
use crate::util::rng::Rng;

use super::render_time_table;

pub fn run(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    let mut out = String::from("=== Async: event-driven simulation (per-worker clocks) ===\n\n");
    out.push_str(&scale_panel(base, out_dir, quick)?);
    out.push('\n');
    out.push_str(&loss_panel(base, out_dir, quick)?);
    Ok(out)
}

/// Panel 1: the scenario sweep + N-sweep (timing-only).
fn scale_panel(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    let mut scenario = Scenario {
        name: "async-ring".into(),
        workers: if quick { 1000 } else { 4000 },
        iters: if quick { 25 } else { 60 },
        seed: base.train.seed,
        policies: vec![
            WaitPolicy::Full,
            WaitPolicy::Static { b: 1 },
            WaitPolicy::Dybw,
        ],
        ..Scenario::default()
    };
    scenario.compute = base.straggler_base;
    scenario.transient_factor = base.straggler_factor;
    let mut out = scenario.run(out_dir, None)?;

    // N-sweep: per-worker pace (makespan / iters) versus cluster size.
    let sizes: &[usize] = if quick { &[100, 400, 1000] } else { &[100, 1000, 4000] };
    out.push_str("\n--- per-worker pace vs cluster size (ring, identical model) ---\n");
    out.push_str(&format!(
        "{:>8} | {:>14} {:>14} {:>10}\n",
        "N", "full s/iter", "dybw s/iter", "ratio"
    ));
    for &n in sizes {
        // the scenario's OWN model at each size, so the N-sweep rows are
        // consistent with the policy table printed above them
        let mut scn = scenario.clone();
        scn.workers = n;
        let iters = scn.iters;
        let mut rng = Rng::new(scn.seed);
        let model = scn.straggler_model(&mut rng);
        let trace = std::sync::Arc::new(Trace::record(&model, iters, &mut rng));
        let link = scn.link_model();
        let pace = |policy: WaitPolicy| -> anyhow::Result<f64> {
            let mut sim = ClusterSim::new(
                topology::ring(n),
                policy,
                iters,
                ComputeTimes::Replay(trace.clone()),
                link.clone(),
            )?;
            let stats = sim.run(&mut NoHooks)?;
            Ok(stats.makespan / iters as f64)
        };
        let (full, dybw) = (pace(WaitPolicy::Full)?, pace(WaitPolicy::Dybw)?);
        out.push_str(&format!(
            "{:>8} | {:>13.4}s {:>13.4}s {:>10.2}\n",
            n,
            full,
            dybw,
            full / dybw
        ));
    }
    out.push_str(
        "(per-worker pace stays ~flat as N grows while total work grows ~N: aggregate\n \
         throughput scales linearly — Cor. 2/3's speedup on the async timeline — and\n \
         dybw holds a constant-factor pace lead over the full barrier at every size)\n",
    );
    Ok(out)
}

/// Panel 2: full-fidelity time-vs-loss, cb-DyBW vs full barrier.
///
/// One realisation per (scenario, seed): the compute-time trace is
/// recorded once up front and shared by `Arc` across the policy cells
/// on the [`super::run_cells`] scheduler, so dybw-vs-full is an A/B on
/// literally the same realisation — previously each cell re-recorded
/// an identical trace from scratch inside its own build.
fn loss_panel(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    let iters = if quick { 40 } else { 200 };
    let mut shared = super::cell_setup(base);
    shared.model = "lrm_d64_c10_b256".into();
    shared.train.iters = iters;
    shared.train.eval_every = (iters / 20).max(1);
    let trace = shared.record_des_trace();
    let jobs: Vec<_> = [WaitPolicy::Dybw, WaitPolicy::Full]
        .into_iter()
        .map(|policy| {
            let s = shared.clone();
            let trace = trace.clone();
            move || -> anyhow::Result<RunHistory> {
                let link = LinkModel::new(
                    0.002,
                    Some(Dist::ShiftedExp { base: 0.0, rate: 800.0 }),
                    s.train.seed,
                );
                let mut trainer =
                    s.build_des_with_times(policy, link, Some(ComputeTimes::Replay(trace)))?;
                let o = trainer.run()?;
                export::write_csv(&o.history, out_dir, &format!("async.{}", policy.name()))?;
                Ok(o.history)
            }
        })
        .collect();
    let hists = super::run_cells(jobs)?;
    let mut out = String::from("--- time vs loss, full-fidelity DES (6 workers, LRM) ---\n");
    out.push_str(&render_time_table(&hists[0], &hists[1], &[0.55]));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn async_figure_quick() {
        let dir = std::env::temp_dir().join("dybw_asyncfig_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Setup::default();
        s.train_n = 2400;
        s.test_n = 1024;
        let out = run(&s, &dir, true).unwrap();
        assert!(out.contains("dybw"), "{out}");
        assert!(out.contains("per-worker pace"));
        assert!(dir.join("async.dybw.evals.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
