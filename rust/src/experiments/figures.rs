//! The paper's figures and table, regenerated.
//!
//! Scale note: the paper trains real MNIST/CIFAR on a 6-machine NFS+MPI
//! testbed; we train the synthetic stand-ins (DESIGN.md §Substitutions)
//! under the simulated straggler model. Absolute losses/durations differ;
//! the *comparisons* the paper reports — similar iteration counts, 55-70%
//! iteration-duration reduction, ~60%+ convergence-time reduction, a
//! visibly time-varying backup-worker count — are the reproduction target
//! (EXPERIMENTS.md records paper-vs-measured for each).

use std::path::Path;

use crate::coordinator::setup::{DatasetProfile, Setup};
use crate::coordinator::Algorithm;
use crate::graph::topology;
use crate::metrics::export;
use crate::metrics::RunHistory;
use crate::model::ModelMeta;

use super::{render_duration_table, render_eval_table, render_time_table};

/// Run one (algo, dataset, model) cell and export its CSVs.
///
/// Cells are independent (own Setup, own data, own pool, distinct export
/// prefixes) and bit-deterministic given the seed, which is what lets
/// the figure harnesses fan them over [`super::run_cells`]' bounded
/// scheduler: concurrent output is byte-identical to sequential.
pub(crate) fn run_cell(
    base: &Setup,
    algo: Algorithm,
    dataset: DatasetProfile,
    model: &str,
    iters: usize,
    out_dir: &Path,
    tag: &str,
) -> anyhow::Result<RunHistory> {
    let mut s = base.clone();
    s.algo = algo;
    s.dataset = dataset;
    s.model = model.to_string();
    s.train.iters = iters;
    s.train.eval_every = (iters / 25).max(1);
    let mut trainer = s.build_sim()?;
    let mut h = trainer.run()?;
    h.dataset = dataset.name().into();
    h.model = model.into();
    let prefix = format!("{tag}.{}.{}", dataset.name(), algo.name().to_lowercase());
    export::write_csv(&h, out_dir, &prefix)?;
    export::write_json(&h, out_dir, &prefix)?;
    Ok(h)
}

/// The dataset × {cb-DyBW, cb-Full} grid behind figs 1/4/6: all four
/// cells run concurrently (bounded by the cell scheduler), the report is
/// assembled in grid order afterwards.
fn err_loss_duration_figure(
    base: &Setup,
    model: &str,
    iters: usize,
    out_dir: &Path,
    tag: &str,
    title: &str,
) -> anyhow::Result<String> {
    let datasets = [DatasetProfile::MnistLike, DatasetProfile::CifarLike];
    let cells: Vec<(DatasetProfile, Algorithm)> = datasets
        .iter()
        .flat_map(|&d| [(d, Algorithm::CbDybw), (d, Algorithm::CbFull)])
        .collect();
    let jobs: Vec<_> = cells
        .iter()
        .map(|&(dataset, algo)| {
            let s = super::cell_setup(base);
            move || run_cell(&s, algo, dataset, model, iters, out_dir, tag)
        })
        .collect();
    let mut hists = super::run_cells(jobs)?;
    let mut out = format!("=== {title} ===\n");
    for dataset in datasets {
        let dybw = hists.remove(0);
        let full = hists.remove(0);
        out.push_str(&format!(
            "\n--- {} / {} / {} workers ---\n",
            dataset.name(),
            model,
            base.workers
        ));
        out.push_str("(a)+(b) error & loss vs iteration:\n");
        out.push_str(&render_eval_table(&dybw, &full));
        out.push_str("(c)+(d) iteration duration & backup workers:\n");
        out.push_str(&render_duration_table(&dybw, &full));
    }
    Ok(out)
}

/// Figure 1: LRM on MNIST-like and CIFAR-like, 6 workers.
pub fn fig1(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    let iters = if quick { 40 } else { 400 };
    err_loss_duration_figure(
        base,
        "lrm_d64_c10_b256",
        iters,
        out_dir,
        "fig1",
        "Figure 1: cb-DyBW vs cb-Full, LRM (6 workers)",
    )
}

/// Figure 2: the 10-worker connected network (topology report).
pub fn fig2(base: &Setup) -> anyhow::Result<String> {
    let g = topology::paper_fig2(base.train.seed);
    let mut out = String::from("=== Figure 2: 10-worker connected network ===\n");
    out.push_str(&format!(
        "nodes={} edges={} diameter={:?} connected={}\n",
        g.n(),
        g.edge_count(),
        crate::graph::paths::diameter(&g),
        g.is_connected()
    ));
    for v in 0..g.n() {
        let nbrs: Vec<String> = g.neighbors(v).map(|u| u.to_string()).collect();
        out.push_str(&format!("  worker {v}: neighbours [{}]\n", nbrs.join(", ")));
    }
    let p = crate::graph::paths::connecting_path(&g);
    out.push_str(&format!(
        "DTUR connecting path P ({} links): {:?}\n",
        p.len(),
        p
    ));
    Ok(out)
}

/// Figure 3: impact of batch size (paper: 1,024 is the sweet spot).
pub fn fig3(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    let iters = if quick { 30 } else { 250 };
    let batches: &[usize] = if quick { &[64, 256] } else { &[128, 256, 512, 1024, 2048] };
    let mut out = String::from("=== Figure 3: impact of batch size (LRM, cb-DyBW) ===\n");
    for dataset in [DatasetProfile::MnistLike, DatasetProfile::CifarLike] {
        out.push_str(&format!("\n--- {} ---\n", dataset.name()));
        out.push_str(&format!(
            "{:>8} | {:>10} {:>12} {:>14} {:>16}\n",
            "batch", "final err%", "final loss", "mean T(k) (s)", "loss @ t*0.5"
        ));
        // one concurrent cell per batch size; rows rendered in sweep order
        let jobs: Vec<_> = batches
            .iter()
            .map(|&bsz| {
                let mut s = super::cell_setup(base);
                s.algo = Algorithm::CbDybw;
                s.dataset = dataset;
                s.model = format!("lrm_d64_c10_b{bsz}");
                s.train.iters = iters;
                s.train.eval_every = (iters / 20).max(1);
                // compute time grows with batch size: scale the straggler base
                let scale = bsz as f64 / 256.0;
                s.straggler_base = crate::straggler::Dist::ShiftedExp {
                    base: 0.08 * scale,
                    rate: 25.0 / scale,
                };
                move || -> anyhow::Result<RunHistory> {
                    let mut trainer = s.build_sim()?;
                    let h = trainer.run()?;
                    let prefix = format!("fig3.{}.b{bsz}", s.dataset.name());
                    export::write_csv(&h, out_dir, &prefix)?;
                    Ok(h)
                }
            })
            .collect();
        let hists = super::run_cells(jobs)?;
        for (&bsz, h) in batches.iter().zip(&hists) {
            let final_eval = h.final_eval().unwrap();
            let half_t = h.total_time() * 0.5;
            let mid = h
                .evals
                .iter()
                .take_while(|e| e.clock <= half_t)
                .last()
                .map(|e| format!("{:.4}", e.test_loss))
                .unwrap_or_else(|| "-".into());
            out.push_str(&format!(
                "{:>8} | {:>10.1} {:>12.4} {:>14.3} {:>16}\n",
                bsz,
                final_eval.test_error * 100.0,
                final_eval.test_loss,
                h.mean_iter_duration(),
                mid
            ));
        }
    }
    out.push_str(
        "\n(paper: marginal improvement shrinks with batch size; 1,024 balances\n progress per iteration against iteration duration)\n",
    );
    Ok(out)
}

/// Figure 4: 2NN (Table 1 architecture) on both datasets.
pub fn fig4(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    let iters = if quick { 30 } else { 300 };
    let model = if quick { "mlp2_d64_h64_c10_b128" } else { "mlp2_d64_h256_c10_b256" };
    err_loss_duration_figure(
        base,
        model,
        iters,
        out_dir,
        "fig4",
        "Figure 4: cb-DyBW vs cb-Full, 2NN (6 workers)",
    )
}

/// Figure 5: 2NN loss versus wall-clock time + convergence-time reduction.
pub fn fig5(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    let iters = if quick { 30 } else { 300 };
    let model = if quick { "mlp2_d64_h64_c10_b128" } else { "mlp2_d64_h256_c10_b256" };
    let mut out = String::from("=== Figure 5: loss vs time, 2NN ===\n");
    // Targets sit just above each run's loss floor (the paper's 0.1/0.75
    // are for real MNIST/CIFAR; our mixtures bottom out higher).
    let cells = [
        (DatasetProfile::MnistLike, 0.45),
        (DatasetProfile::CifarLike, 2.2),
    ];
    let mut hists = loss_vs_time_cells(base, &cells, model, iters, out_dir, "fig5")?;
    for (dataset, target) in cells {
        let dybw = hists.remove(0);
        let full = hists.remove(0);
        out.push_str(&format!("\n--- {} ---\n", dataset.name()));
        out.push_str(&render_time_table(&dybw, &full, &[target]));
    }
    Ok(out)
}

/// The {dataset} × {cb-DyBW, cb-Full} cells behind figs 5/7, run
/// concurrently; returns histories in (dataset-major, dybw-then-full)
/// order.
fn loss_vs_time_cells(
    base: &Setup,
    cells: &[(DatasetProfile, f64)],
    model: &str,
    iters: usize,
    out_dir: &Path,
    tag: &str,
) -> anyhow::Result<Vec<RunHistory>> {
    let jobs: Vec<_> = cells
        .iter()
        .flat_map(|&(d, _)| [(d, Algorithm::CbDybw), (d, Algorithm::CbFull)])
        .map(|(dataset, algo)| {
            let s = super::cell_setup(base);
            move || run_cell(&s, algo, dataset, model, iters, out_dir, tag)
        })
        .collect();
    super::run_cells(jobs)
}

/// Figure 6: LRM on the 10-worker network (Appendix B).
pub fn fig6(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    let iters = if quick { 30 } else { 300 };
    let mut b10 = base.clone();
    b10.workers = 10;
    err_loss_duration_figure(
        &b10,
        "lrm_d64_c10_b256",
        iters,
        out_dir,
        "fig6",
        "Figure 6: cb-DyBW vs cb-Full, LRM (10 workers, Fig. 2 network)",
    )
}

/// Figure 7: LRM loss versus time (Appendix B).
pub fn fig7(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    let iters = if quick { 30 } else { 300 };
    let mut b10 = base.clone();
    b10.workers = 10;
    let mut out = String::from("=== Figure 7: loss vs time, LRM (10 workers) ===\n");
    let cells = [
        (DatasetProfile::MnistLike, 0.5),
        (DatasetProfile::CifarLike, 2.2),
    ];
    let mut hists = loss_vs_time_cells(&b10, &cells, "lrm_d64_c10_b256", iters, out_dir, "fig7")?;
    for (dataset, target) in cells {
        let dybw = hists.remove(0);
        let full = hists.remove(0);
        out.push_str(&format!("\n--- {} ---\n", dataset.name()));
        out.push_str(&render_time_table(&dybw, &full, &[target]));
    }
    Ok(out)
}

/// Table 1: the 2NN architecture (parameter inventory).
pub fn table1() -> anyhow::Result<String> {
    let meta = ModelMeta::mlp2(256, 256, 10, 1024);
    let mut out = String::from("=== Table 1: 2NN architecture (inputs PCA'd to 256) ===\n");
    out.push_str(&format!(
        "{:<28} {:>14} {:>10}\n",
        "layer", "shape", "params"
    ));
    let rows = [
        ("Fully Connected + ReLU", "w1/b1"),
        ("Fully Connected + ReLU", "w2/b2"),
        ("Fully Connected + SoftMax", "w3/b3"),
    ];
    for (i, (label, _)) in rows.iter().enumerate() {
        let w = &meta.segments[i * 2];
        let b = &meta.segments[i * 2 + 1];
        out.push_str(&format!(
            "{:<28} {:>14} {:>10}\n",
            label,
            format!("{}x{}", w.shape[0], w.shape[1]),
            w.size + b.size
        ));
    }
    out.push_str(&format!(
        "{:<28} {:>14} {:>10}\n",
        "total", "", meta.param_count
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_setup() -> Setup {
        let mut s = Setup::default();
        s.train_n = 2400;
        s.test_n = 1024;
        s.train.seed = 11;
        s
    }

    #[test]
    fn table1_matches_paper_architecture() {
        let t = table1().unwrap();
        assert!(t.contains("256x256"));
        assert!(t.contains("256x10"));
    }

    #[test]
    fn fig2_prints_connected_topology() {
        let t = fig2(&Setup::default()).unwrap();
        assert!(t.contains("connected=true"));
        assert!(t.contains("9 links"));
    }

    #[test]
    fn fig1_quick_shows_reduction() {
        let dir = std::env::temp_dir().join("dybw_fig1_test");
        let out = fig1(&quick_setup(), &dir, true).unwrap();
        assert!(out.contains("duration reduction"));
        assert!(out.contains("mnist-like"));
        assert!(out.contains("cifar-like"));
        // CSVs written
        assert!(dir.join("fig1.mnist-like.cb-dybw.iters.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fig3_quick_runs() {
        let dir = std::env::temp_dir().join("dybw_fig3_test");
        let out = fig3(&quick_setup(), &dir, true).unwrap();
        assert!(out.contains("batch"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fig5_quick_reports_time_to_loss() {
        let dir = std::env::temp_dir().join("dybw_fig5_test");
        let out = fig5(&quick_setup(), &dir, true).unwrap();
        assert!(out.contains("time to loss"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
