//! Experiment harnesses: regenerate every table and figure in the paper.
//!
//! | id       | paper artefact                            | harness |
//! |----------|-------------------------------------------|---------|
//! | fig1     | Fig. 1: LRM err/loss/duration/backup, 6 w | [`figures::fig1`] |
//! | fig2     | Fig. 2: the 10-worker connected network   | [`figures::fig2`] |
//! | fig3     | Fig. 3: impact of batch size              | [`figures::fig3`] |
//! | fig4     | Fig. 4: 2NN err/loss/duration/backup      | [`figures::fig4`] |
//! | fig5     | Fig. 5: 2NN loss vs wall-clock time       | [`figures::fig5`] |
//! | fig6     | Fig. 6: LRM on the 10-worker network      | [`figures::fig6`] |
//! | fig7     | Fig. 7: LRM loss vs wall-clock time       | [`figures::fig7`] |
//! | table1   | Table 1: 2NN architecture                 | [`figures::table1`] |
//! | speedup  | Cor. 2/3: linear speedup in N             | [`speedup::run`] |
//! | baselines| §1/§related: static-b + PS comparisons    | [`ablation::baselines`] |
//! | topology | β^{NB} sensitivity: ring/grid/complete    | [`ablation::topology`] |
//! | severity | straggler-severity sweep (crossover)      | [`ablation::severity`] |
//! | async    | DES: per-worker clocks, scale + time-loss | [`asyncfig::run`] |
//!
//! Each harness prints the same series the paper plots (downsampled for
//! stdout) and writes full-resolution CSV/JSON under `--out-dir`.

pub mod ablation;
pub mod asyncfig;
pub mod figures;
pub mod speedup;

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::coordinator::setup::Setup;
use crate::metrics::RunHistory;

// ---------------------------------------------------------------------------
// concurrent cell scheduler
// ---------------------------------------------------------------------------

/// Configured cap on concurrently-running harness cells (0 = auto).
static CELL_CAP: AtomicUsize = AtomicUsize::new(0);

/// Cap the number of harness cells (independent `Setup` builds + runs)
/// executing concurrently inside `run_cells`. 0 = auto: half the cores,
/// clamped to [1, 4], which bounds peak memory (each cell owns one
/// dataset + one engine pool). Outputs are always assembled in
/// submission order and every cell is bit-deterministic given its seed,
/// so this knob never changes results — only wall clock and memory.
pub fn set_cell_concurrency(cap: usize) {
    CELL_CAP.store(cap, Ordering::Relaxed);
}

pub(crate) fn cell_concurrency() -> usize {
    match CELL_CAP.load(Ordering::Relaxed) {
        0 => (std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1) / 2).clamp(1, 4),
        cap => cap,
    }
}

/// Clone `base` for one concurrently-running cell: auto-sized pools
/// shrink so `cell_concurrency()` simultaneous cells share the machine
/// instead of oversubscribing it (an explicit `--threads` is respected).
/// The lane count never changes results (the bit-identity invariant), so
/// this is purely a scheduling choice.
pub(crate) fn cell_setup(base: &Setup) -> Setup {
    let mut s = base.clone();
    if s.threads == 0 {
        let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        s.threads = (cores / cell_concurrency()).max(1);
    }
    s
}

/// Run independent harness cells with bounded concurrency on a small
/// scoped-thread scheduler. Results come back in submission order and
/// errors surface lowest-index-first, so output assembly is
/// deterministic no matter how cells raced; with a cap of 1 the jobs run
/// inline on the caller thread (the sequential reference path).
pub(crate) fn run_cells<T, F>(jobs: Vec<F>) -> anyhow::Result<Vec<T>>
where
    T: Send,
    F: FnOnce() -> anyhow::Result<T> + Send,
{
    let lanes = cell_concurrency().min(jobs.len().max(1));
    if lanes <= 1 {
        return jobs.into_iter().map(|f| f()).collect();
    }
    let n = jobs.len();
    let jobs: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|f| Mutex::new(Some(f))).collect();
    let slots: Vec<Mutex<Option<anyhow::Result<T>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..lanes {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let job = jobs[i].lock().unwrap_or_else(|p| p.into_inner()).take();
                if let Some(job) = job {
                    let result = job();
                    *slots[i].lock().unwrap_or_else(|p| p.into_inner()) = Some(result);
                }
            });
        }
    });
    slots
        .into_iter()
        .enumerate()
        .map(|(i, slot)| {
            let inner = slot.into_inner().unwrap_or_else(|p| p.into_inner());
            match inner {
                Some(result) => result,
                None => Err(anyhow::anyhow!("harness cell {i} produced no result")),
            }
        })
        .collect()
}

/// All experiment ids, in presentation order.
pub const ALL: &[&str] = &[
    "table1", "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "speedup", "baselines",
    "topology", "severity", "compression", "async",
];

/// Dispatch by id. `quick` shrinks workloads (used by tests/CI).
pub fn run(id: &str, base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    match id {
        "fig1" => figures::fig1(base, out_dir, quick),
        "fig2" => figures::fig2(base),
        "fig3" => figures::fig3(base, out_dir, quick),
        "fig4" => figures::fig4(base, out_dir, quick),
        "fig5" => figures::fig5(base, out_dir, quick),
        "fig6" => figures::fig6(base, out_dir, quick),
        "fig7" => figures::fig7(base, out_dir, quick),
        "table1" => figures::table1(),
        "speedup" => speedup::run(base, out_dir, quick),
        "baselines" => ablation::baselines(base, out_dir, quick),
        "topology" => ablation::topology(base, out_dir, quick),
        "severity" => ablation::severity(base, out_dir, quick),
        "compression" => ablation::compression(base, out_dir, quick),
        "async" => asyncfig::run(base, out_dir, quick),
        "all" => {
            let mut out = String::new();
            for id in ALL {
                out.push_str(&run(id, base, out_dir, quick)?);
                out.push('\n');
            }
            Ok(out)
        }
        _ => anyhow::bail!("unknown experiment '{id}' (known: {ALL:?} or 'all')"),
    }
}

// ---------------------------------------------------------------------------
// shared rendering helpers
// ---------------------------------------------------------------------------

/// Downsample an iteration-indexed series to ~`points` rows.
pub(crate) fn sample_series(len: usize, points: usize) -> Vec<usize> {
    if len == 0 {
        return Vec::new();
    }
    let step = (len / points.max(1)).max(1);
    let mut idx: Vec<usize> = (0..len).step_by(step).collect();
    if *idx.last().unwrap() != len - 1 {
        idx.push(len - 1);
    }
    idx
}

/// Two-run aligned eval table: err and loss per iteration (Fig 1a/1b style).
pub(crate) fn render_eval_table(a: &RunHistory, b: &RunHistory) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:>6} | {:>10} {:>10} | {:>10} {:>10}   (test error %, train-side loss from eval)\n",
        "iter",
        format!("{} err", a.algo),
        format!("{} err", b.algo),
        format!("{} loss", a.algo),
        format!("{} loss", b.algo),
    ));
    let n = a.evals.len().min(b.evals.len());
    for i in sample_series(n, 12) {
        let (ea, eb) = (&a.evals[i], &b.evals[i]);
        out.push_str(&format!(
            "{:>6} | {:>10.1} {:>10.1} | {:>10.4} {:>10.4}\n",
            ea.k,
            ea.test_error * 100.0,
            eb.test_error * 100.0,
            ea.test_loss,
            eb.test_loss
        ));
    }
    out
}

/// Duration + backup-worker table (Fig 1c/1d style).
pub(crate) fn render_duration_table(a: &RunHistory, b: &RunHistory) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:>6} | {:>12} {:>12} | {:>12}\n",
        "iter",
        format!("{} T(k)", a.algo),
        format!("{} T(k)", b.algo),
        "backup b(k)"
    ));
    let n = a.iters.len().min(b.iters.len());
    for i in sample_series(n, 10) {
        out.push_str(&format!(
            "{:>6} | {:>11.3}s {:>11.3}s | {:>12.2}\n",
            a.iters[i].k, a.iters[i].duration, b.iters[i].duration, a.iters[i].backup_avg
        ));
    }
    out.push_str(&format!(
        "  mean | {:>11.3}s {:>11.3}s | {:>12.2}   -> duration reduction {:.0}%\n",
        a.mean_iter_duration(),
        b.mean_iter_duration(),
        a.mean_backup_workers(),
        (1.0 - a.mean_iter_duration() / b.mean_iter_duration()) * 100.0
    ));
    out
}

/// Loss-versus-time table (Fig 5/7 style).
pub(crate) fn render_time_table(a: &RunHistory, b: &RunHistory, targets: &[f64]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:>10} | {:>12} {:>12}   (test loss at wall-clock time)\n",
        "time", &a.algo, &b.algo
    ));
    let t_max = a.total_time().max(b.total_time());
    for frac in [0.05, 0.1, 0.2, 0.35, 0.5, 0.7, 0.85, 1.0] {
        let t = t_max * frac;
        let pick = |h: &RunHistory| {
            h.evals
                .iter()
                .take_while(|e| e.clock <= t)
                .last()
                .map(|e| format!("{:.4}", e.test_loss))
                .unwrap_or_else(|| "-".into())
        };
        out.push_str(&format!("{:>9.1}s | {:>12} {:>12}\n", t, pick(a), pick(b)));
    }
    for &target in targets {
        let fmt = |v: Option<f64>| v.map(|x| format!("{x:.1}s")).unwrap_or_else(|| "n/a".into());
        let (ta, tb) = (a.time_to_test_loss(target), b.time_to_test_loss(target));
        out.push_str(&format!(
            "  time to loss {:.2}: {} vs {}{}\n",
            target,
            fmt(ta),
            fmt(tb),
            match (ta, tb) {
                (Some(x), Some(y)) if y > 0.0 =>
                    format!("  -> convergence-time reduction {:.0}%", (1.0 - x / y) * 100.0),
                _ => String::new(),
            }
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_series_covers_ends() {
        let idx = sample_series(100, 10);
        assert_eq!(*idx.first().unwrap(), 0);
        assert_eq!(*idx.last().unwrap(), 99);
        assert!(idx.len() <= 12);
        assert!(sample_series(0, 5).is_empty());
        assert_eq!(sample_series(3, 10), vec![0, 1, 2]);
    }

    #[test]
    fn unknown_experiment_errors() {
        let s = Setup::default();
        assert!(run("fig99", &s, Path::new("/tmp"), true).is_err());
    }

    #[test]
    fn run_cells_preserves_submission_order() {
        set_cell_concurrency(3);
        // later cells finish first; results must still come back in order
        let jobs: Vec<_> = (0..7usize)
            .map(|i| {
                move || -> anyhow::Result<usize> {
                    std::thread::sleep(std::time::Duration::from_millis((7 - i) as u64 * 3));
                    Ok(i)
                }
            })
            .collect();
        let got = run_cells(jobs).unwrap();
        set_cell_concurrency(0);
        assert_eq!(got, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn run_cells_surfaces_lowest_index_error() {
        set_cell_concurrency(2);
        let jobs: Vec<_> = (0..5usize)
            .map(|i| {
                move || -> anyhow::Result<usize> {
                    anyhow::ensure!(i % 2 == 0, "cell {i} failed");
                    Ok(i)
                }
            })
            .collect();
        let err = run_cells(jobs).unwrap_err();
        set_cell_concurrency(0);
        assert!(err.to_string().contains("cell 1 failed"), "{err}");
    }

    #[test]
    fn cell_setup_reduces_auto_lanes_only() {
        let mut base = Setup::default();
        base.threads = 0;
        assert!(cell_setup(&base).threads >= 1);
        base.threads = 7;
        assert_eq!(cell_setup(&base).threads, 7);
    }
}
