//! Linear-speedup validation (Corollaries 2-3) + engine-pool wall clock.
//!
//! Theory: with η = √(N/K) the convergence rate is O(1/√(NK) + 1/K), so
//! the number of iterations to reach ε-accuracy scales like 1/N — "linear
//! speedup for convergence". We sweep N, hold everything else fixed
//! (including the TOTAL dataset size, so more workers = more parallel
//! data), and report iterations-to-target and the N·K̃ product, which the
//! theory predicts approximately constant once K is large enough.
//!
//! The second section measures the *system* speedup delivered by the
//! [`EnginePool`](crate::engine::EnginePool) refactor: identical 16-worker
//! 2NN training (bit-identical histories), sequential (1 lane) vs pooled
//! (4 lanes), plus the eq. (6) mixing phase in isolation (sequential loop
//! vs pooled row fan-out at figure-scale dimension), all reported as
//! wall-clock seconds and written to `BENCH_speedup.json` so CI can track
//! the perf trajectory. [`gate`] turns that JSON into a regression gate
//! against a committed baseline.

use std::path::Path;
use std::time::Instant;

use crate::consensus::mixing::ParamBuffers;
use crate::consensus::ConsensusMatrix;
use crate::coordinator::setup::Setup;
use crate::coordinator::Algorithm;
use crate::engine::EnginePool;
use crate::metrics::export;
use crate::util::json::Json;
use crate::util::rng::Rng;

pub fn run(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    let ns: &[usize] = if quick { &[2, 4] } else { &[2, 4, 6, 8, 12, 16] };
    let iters = if quick { 60 } else { 400 };
    let target = 0.55; // test loss target for the easy LRM task
    let mut out =
        String::from("=== Linear speedup (Corollary 2/3): iterations to target vs N ===\n");
    out.push_str(&format!(
        "{:>4} | {:>12} {:>10} {:>12} {:>14}\n",
        "N", "iters to", "N x K", "final loss", "mean T(k) (s)"
    ));
    let mut prev_k: Option<usize> = None;
    for &n in ns {
        let mut s = base.clone();
        s.workers = n;
        s.algo = Algorithm::CbDybw;
        s.model = "lrm_d64_c10_b256".into();
        s.train.iters = iters;
        s.train.eval_every = 5;
        // Corollary 2's schedule: η = √(N/K) (clamped for stability).
        s.train.lr0 = (n as f64 / iters as f64).sqrt().min(0.5);
        s.train.lr_decay = 1.0;
        let mut trainer = s.build_sim()?;
        let h = trainer.run()?;
        export::write_csv(&h, out_dir, &format!("speedup.n{n}"))?;
        let k_target = h.iters_to_test_loss(target);
        let final_loss = h.final_eval().map(|e| e.test_loss).unwrap_or(f64::NAN);
        out.push_str(&format!(
            "{:>4} | {:>12} {:>10} {:>12.4} {:>14.3}\n",
            n,
            k_target.map(|k| k.to_string()).unwrap_or_else(|| "n/a".into()),
            k_target.map(|k| (n * k).to_string()).unwrap_or_else(|| "-".into()),
            final_loss,
            h.mean_iter_duration()
        ));
        if let (Some(prev), Some(cur)) = (prev_k, k_target) {
            // monotone non-increasing iterations with more workers
            // (allow slack for stochastic wiggle)
            if cur as f64 > prev as f64 * 1.5 {
                out.push_str(&format!(
                    "  !! speedup violated between N and previous row ({prev} -> {cur})\n"
                ));
            }
        }
        prev_k = k_target.or(prev_k);
    }
    out.push_str("(theory: K_eps ~ 1/(eps^2 N); N x K approximately constant)\n");
    out.push('\n');
    out.push_str(&pool_wall_clock(base, out_dir, quick)?);
    Ok(out)
}

/// Sequential-vs-pooled sim-driver wall clock on the 16-worker 2NN
/// workload. Same seed -> bit-identical histories; only the clock moves.
pub fn pool_wall_clock(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    const POOL_THREADS: usize = 4;
    let mut s = base.clone();
    s.workers = 16;
    s.algo = Algorithm::CbDybw;
    s.model = "mlp2_d64_h256_c10_b256".into();
    s.train_n = if quick { 4_096 } else { 16_384 };
    s.test_n = 512;
    s.train.iters = if quick { 3 } else { 20 };
    s.train.eval_every = 0;

    let timed = |threads: usize| -> anyhow::Result<(f64, crate::metrics::RunHistory)> {
        let mut s2 = s.clone();
        s2.threads = threads;
        let mut trainer = s2.build_sim()?;
        let t0 = Instant::now();
        let h = trainer.run()?;
        Ok((t0.elapsed().as_secs_f64(), h))
    };
    // Best-of-3 wall clock in release (where CI gates on the ratio):
    // repetitions are bit-identical (fresh trainer, same seed — enforced),
    // only the clock varies, and min rejects shared-runner noise. Debug
    // builds (the plain `cargo test` path) take one sample — the numbers
    // are not gated there and the naive-loop repetitions would be slow.
    let reps = if cfg!(debug_assertions) { 1 } else { 3 };
    let best = |threads: usize| -> anyhow::Result<(f64, crate::metrics::RunHistory)> {
        let (mut best_s, h) = timed(threads)?;
        for _ in 1..reps {
            let (s2, h2) = timed(threads)?;
            anyhow::ensure!(h.bits_eq(&h2), "repeated speedup runs diverged (nondeterminism)");
            best_s = best_s.min(s2);
        }
        Ok((best_s, h))
    };
    let (seq_s, seq_h) = best(1)?;
    let (pool_s, pool_h) = best(POOL_THREADS)?;
    let speedup = seq_s / pool_s.max(1e-12);
    let identical = seq_h.bits_eq(&pool_h);
    let seq_loss = seq_h.iters.last().map(|r| r.train_loss).unwrap_or(f64::NAN);
    let pool_loss = pool_h.iters.last().map(|r| r.train_loss).unwrap_or(f64::NAN);

    let mut out = String::from(
        "=== Engine-pool wall clock: sequential vs pooled sim driver ===\n",
    );
    out.push_str(&format!(
        "workload: {} / 16 workers / {} iters\n",
        s.model, s.train.iters
    ));
    out.push_str(&format!("  threads=1 (baseline)  : {seq_s:.3}s wall\n"));
    out.push_str(&format!("  threads={POOL_THREADS} (pooled)    : {pool_s:.3}s wall\n"));
    out.push_str(&format!(
        "  speedup               : {speedup:.2}x  (hardware parallelism: {})\n",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    ));
    out.push_str(&format!(
        "  bit-identical history : {identical}  (final train loss {seq_loss:.6} vs {pool_loss:.6})\n"
    ));

    let mix = mix_phase(quick)?;
    out.push_str(&mix.report());

    let mut j = Json::obj();
    j.set("bench", "pool_speedup".into())
        .set("model", s.model.as_str().into())
        .set("workers", s.workers.into())
        .set("iters", s.train.iters.into())
        .set("quick", quick.into())
        .set("threads_pool", POOL_THREADS.into())
        .set(
            "hardware_parallelism",
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).into(),
        )
        .set("seq_seconds", seq_s.into())
        .set("pool_seconds", pool_s.into())
        .set("speedup", speedup.into())
        .set("bit_identical", identical.into())
        .set("mix_workers", mix.n.into())
        .set("mix_dim", mix.dim.into())
        .set("mix_rounds", mix.rounds.into())
        .set("mix_threads", mix.threads.into())
        .set("mix_seq_seconds", mix.seq_s.into())
        .set("mix_pool_seconds", mix.pool_s.into())
        .set("mix_speedup", mix.speedup.into())
        .set("mix_bit_identical", mix.identical.into());
    std::fs::create_dir_all(out_dir)?;
    let path = out_dir.join("BENCH_speedup.json");
    std::fs::write(&path, j.to_string())?;
    out.push_str(&format!("(bench JSON -> {})\n", path.display()));
    Ok(out)
}

/// Result of the mix-phase sequential-vs-pooled measurement.
struct MixPhase {
    n: usize,
    dim: usize,
    rounds: usize,
    threads: usize,
    seq_s: f64,
    pool_s: f64,
    speedup: f64,
    identical: bool,
}

impl MixPhase {
    fn report(&self) -> String {
        let mut out =
            String::from("=== Mixing-phase wall clock: sequential vs pooled eq. (6) ===\n");
        out.push_str(&format!(
            "workload: {} workers x {} params x {} rounds (Metropolis, full participation)\n",
            self.n, self.dim, self.rounds
        ));
        out.push_str(&format!("  sequential loop       : {:.3}s wall\n", self.seq_s));
        out.push_str(&format!(
            "  pooled ({} lanes)      : {:.3}s wall\n",
            self.threads, self.pool_s
        ));
        out.push_str(&format!("  speedup               : {:.2}x\n", self.speedup));
        out.push_str(&format!("  bit-identical params  : {}\n", self.identical));
        out
    }
}

/// Time `rounds` eq. (6) mixing rounds at figure-scale dimension, once
/// through the sequential loop and once fanned over a 4-lane pool, and
/// verify the two parameter states match bit for bit.
fn mix_phase(quick: bool) -> anyhow::Result<MixPhase> {
    const POOL_THREADS: usize = 4;
    let n = 16usize;
    let dim = if quick { 262_144 } else { 1_048_576 };
    let rounds = if quick { 12 } else { 40 };
    let mut rng = Rng::new(17);
    let g = crate::graph::topology::random_connected(n, 0.4, &mut rng);
    let pm = ConsensusMatrix::metropolis_full(&g);
    let init: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
        .collect();

    // Best-of-3 wall clock, same rationale as `pool_wall_clock`: every
    // repetition is bit-identical (same init, same P), only the clock
    // varies, and min rejects shared-runner noise.
    let run_rounds = |pool: Option<&EnginePool>| -> anyhow::Result<(f64, ParamBuffers)> {
        let mut bufs = ParamBuffers::from_initial(init.clone());
        let t0 = Instant::now();
        for _ in 0..rounds {
            match pool {
                Some(pool) => bufs.mix_pooled(&pm, pool)?,
                None => bufs.mix(&pm),
            }
        }
        Ok((t0.elapsed().as_secs_f64(), bufs))
    };
    let reps = if cfg!(debug_assertions) { 1 } else { 3 };
    let best = |pool: Option<&EnginePool>| -> anyhow::Result<(f64, ParamBuffers)> {
        let (mut best_s, bufs) = run_rounds(pool)?;
        for _ in 1..reps {
            let (s2, b2) = run_rounds(pool)?;
            for j in 0..bufs.n() {
                anyhow::ensure!(
                    bufs.get(j).iter().zip(b2.get(j)).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "repeated mix runs diverged (nondeterminism)"
                );
            }
            best_s = best_s.min(s2);
        }
        Ok((best_s, bufs))
    };
    let (seq_s, seq) = best(None)?;
    let pool = EnginePool::tasks_only(POOL_THREADS)?;
    let (pool_s, par) = best(Some(&pool))?;

    let identical = (0..n).all(|j| {
        seq.get(j)
            .iter()
            .zip(par.get(j))
            .all(|(a, b)| a.to_bits() == b.to_bits())
    });
    Ok(MixPhase {
        n,
        dim,
        rounds,
        threads: POOL_THREADS,
        seq_s,
        pool_s,
        speedup: seq_s / pool_s.max(1e-12),
        identical,
    })
}

/// CI perf-trajectory gate: compare a freshly measured `BENCH_speedup.json`
/// against the committed baseline. Fails when pooled execution stopped
/// being bit-identical (correctness regression — never tolerated) or when
/// either measured speedup (end-to-end pooled training, or the mixing
/// phase in isolation) dropped below `tolerance` x the baseline value
/// (perf regression beyond noise). Returns the comparison report on pass.
pub fn gate(current: &Path, baseline: &Path, tolerance: f64) -> anyhow::Result<String> {
    anyhow::ensure!(
        (0.0..=1.0).contains(&tolerance),
        "tolerance must be in [0, 1] (got {tolerance})"
    );
    let load = |path: &Path| -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("bad JSON in {}: {e}", path.display()))
    };
    let cur = load(current)?;
    let base = load(baseline)?;

    let mut out = String::from("=== bench gate: current vs committed baseline ===\n");
    let mut failures: Vec<String> = Vec::new();

    // Speedups are only comparable on the same workload: when both files
    // carry a config key, it must match (a baseline written before a
    // workload retune must be refreshed, not silently compared against).
    for key in [
        "quick",
        "threads_pool",
        "workers",
        "iters",
        "mix_workers",
        "mix_dim",
        "mix_rounds",
        "mix_threads",
    ] {
        if let (Some(c), Some(b)) = (cur.get(key), base.get(key)) {
            let (cs, bs) = (c.to_string(), b.to_string());
            anyhow::ensure!(
                cs == bs,
                "workload mismatch on '{key}' ({cs} vs baseline {bs}) — the committed \
                 baseline is stale; refresh it (bench gate --refresh)"
            );
        }
    }

    for key in ["bit_identical", "mix_bit_identical"] {
        // A missing key is a malformed/stale input, not a determinism
        // regression — report it as such.
        let ok = cur
            .get(key)
            .and_then(|v| v.as_bool())
            .ok_or_else(|| anyhow::anyhow!("{} missing '{key}'", current.display()))?;
        out.push_str(&format!("  {key:<18}: {ok}\n"));
        if !ok {
            failures.push(format!("{key} is false — pooled execution diverged"));
        }
    }
    for key in ["speedup", "mix_speedup"] {
        let c = cur
            .get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("{} missing '{key}'", current.display()))?;
        let b = base
            .get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("{} missing '{key}'", baseline.display()))?;
        let floor = b * tolerance;
        let ok = c >= floor;
        out.push_str(&format!(
            "  {key:<18}: {c:.3}x vs baseline {b:.3}x (floor {floor:.3}x) {}\n",
            if ok { "ok" } else { "REGRESSION" }
        ));
        if !ok {
            failures.push(format!(
                "{key} {c:.3}x fell below {floor:.3}x ({tolerance} x baseline {b:.3}x)"
            ));
        }
    }
    if !failures.is_empty() {
        anyhow::bail!("{out}\nperf gate FAILED:\n  - {}", failures.join("\n  - "));
    }
    out.push_str("perf gate passed.\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_quick_runs() {
        let mut s = Setup::default();
        s.train_n = 2400;
        s.test_n = 1024;
        let dir = std::env::temp_dir().join("dybw_speedup_test");
        let out = run(&s, &dir, true).unwrap();
        assert!(out.contains("N x K"));
        assert!(out.contains("Engine-pool wall clock"));
        assert!(out.contains("Mixing-phase wall clock"));
        // the perf-trajectory artifact exists and is valid JSON
        let bench = std::fs::read_to_string(dir.join("BENCH_speedup.json")).unwrap();
        let j = crate::util::json::Json::parse(&bench).unwrap();
        assert_eq!(j.get("bench").and_then(|v| v.as_str()), Some("pool_speedup"));
        assert_eq!(j.get("bit_identical").and_then(|v| v.as_bool()), Some(true));
        assert!(j.get("speedup").and_then(|v| v.as_f64()).unwrap() > 0.0);
        // the mix-phase section is present, bit-identical, and measured
        assert_eq!(j.get("mix_bit_identical").and_then(|v| v.as_bool()), Some(true));
        assert!(j.get("mix_speedup").and_then(|v| v.as_f64()).unwrap() > 0.0);
        assert!(j.get("mix_dim").and_then(|v| v.as_usize()).unwrap() >= 262_144);
        // and a self-gate against the fresh numbers passes trivially
        let path = dir.join("BENCH_speedup.json");
        assert!(gate(&path, &path, 0.75).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gate_detects_regressions() {
        let dir = std::env::temp_dir().join("dybw_gate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let write = |name: &str, speedup: f64, mix: f64, ident: bool| {
            let mut j = Json::obj();
            j.set("speedup", speedup.into())
                .set("mix_speedup", mix.into())
                .set("bit_identical", ident.into())
                .set("mix_bit_identical", true.into());
            let p = dir.join(name);
            std::fs::write(&p, j.to_string()).unwrap();
            p
        };
        let base = write("base.json", 2.0, 2.0, true);
        let good = write("good.json", 1.8, 1.9, true);
        let slow = write("slow.json", 1.0, 1.9, true);
        let slow_mix = write("slow_mix.json", 1.9, 1.2, true);
        let broken = write("broken.json", 2.2, 2.2, false);
        assert!(gate(&good, &base, 0.75).is_ok());
        assert!(gate(&slow, &base, 0.75).is_err(), "grad speedup regression must fail");
        assert!(gate(&slow_mix, &base, 0.75).is_err(), "mix speedup regression must fail");
        assert!(gate(&broken, &base, 0.75).is_err(), "bit-identity loss must fail");
        assert!(gate(&good, &base, 1.5).is_err(), "tolerance > 1 is rejected");
        assert!(gate(&dir.join("missing.json"), &base, 0.75).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
