//! Linear-speedup validation (Corollaries 2-3) + engine-pool wall clock.
//!
//! Theory: with η = √(N/K) the convergence rate is O(1/√(NK) + 1/K), so
//! the number of iterations to reach ε-accuracy scales like 1/N — "linear
//! speedup for convergence". We sweep N, hold everything else fixed
//! (including the TOTAL dataset size, so more workers = more parallel
//! data), and report iterations-to-target and the N·K̃ product, which the
//! theory predicts approximately constant once K is large enough.
//!
//! The second section measures the *system* speedup delivered by the
//! [`EnginePool`](crate::engine::EnginePool) refactor: identical 16-worker
//! 2NN training (bit-identical histories), sequential (1 lane) vs pooled
//! (4 lanes), reported as wall-clock seconds and written to
//! `BENCH_speedup.json` so CI can track the perf trajectory.

use std::path::Path;
use std::time::Instant;

use crate::coordinator::setup::Setup;
use crate::coordinator::Algorithm;
use crate::metrics::export;
use crate::util::json::Json;

pub fn run(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    let ns: &[usize] = if quick { &[2, 4] } else { &[2, 4, 6, 8, 12, 16] };
    let iters = if quick { 60 } else { 400 };
    let target = 0.55; // test loss target for the easy LRM task
    let mut out = String::from("=== Linear speedup (Corollary 2/3): iterations to target vs N ===\n");
    out.push_str(&format!(
        "{:>4} | {:>12} {:>10} {:>12} {:>14}\n",
        "N", "iters to", "N x K", "final loss", "mean T(k) (s)"
    ));
    let mut prev_k: Option<usize> = None;
    for &n in ns {
        let mut s = base.clone();
        s.workers = n;
        s.algo = Algorithm::CbDybw;
        s.model = "lrm_d64_c10_b256".into();
        s.train.iters = iters;
        s.train.eval_every = 5;
        // Corollary 2's schedule: η = √(N/K) (clamped for stability).
        s.train.lr0 = (n as f64 / iters as f64).sqrt().min(0.5);
        s.train.lr_decay = 1.0;
        let mut trainer = s.build_sim()?;
        let h = trainer.run()?;
        export::write_csv(&h, out_dir, &format!("speedup.n{n}"))?;
        let k_target = h.iters_to_test_loss(target);
        let final_loss = h.final_eval().map(|e| e.test_loss).unwrap_or(f64::NAN);
        out.push_str(&format!(
            "{:>4} | {:>12} {:>10} {:>12.4} {:>14.3}\n",
            n,
            k_target.map(|k| k.to_string()).unwrap_or_else(|| "n/a".into()),
            k_target.map(|k| (n * k).to_string()).unwrap_or_else(|| "-".into()),
            final_loss,
            h.mean_iter_duration()
        ));
        if let (Some(prev), Some(cur)) = (prev_k, k_target) {
            // monotone non-increasing iterations with more workers
            // (allow slack for stochastic wiggle)
            if cur as f64 > prev as f64 * 1.5 {
                out.push_str(&format!(
                    "  !! speedup violated between N and previous row ({prev} -> {cur})\n"
                ));
            }
        }
        prev_k = k_target.or(prev_k);
    }
    out.push_str("(theory: K_eps ~ 1/(eps^2 N); N x K approximately constant)\n");
    out.push('\n');
    out.push_str(&pool_wall_clock(base, out_dir, quick)?);
    Ok(out)
}

/// Sequential-vs-pooled sim-driver wall clock on the 16-worker 2NN
/// workload. Same seed -> bit-identical histories; only the clock moves.
pub fn pool_wall_clock(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    const POOL_THREADS: usize = 4;
    let mut s = base.clone();
    s.workers = 16;
    s.algo = Algorithm::CbDybw;
    s.model = "mlp2_d64_h256_c10_b256".into();
    s.train_n = if quick { 4_096 } else { 16_384 };
    s.test_n = 512;
    s.train.iters = if quick { 3 } else { 20 };
    s.train.eval_every = 0;

    let timed = |threads: usize| -> anyhow::Result<(f64, crate::metrics::RunHistory)> {
        let mut s2 = s.clone();
        s2.threads = threads;
        let mut trainer = s2.build_sim()?;
        let t0 = Instant::now();
        let h = trainer.run()?;
        Ok((t0.elapsed().as_secs_f64(), h))
    };
    let (seq_s, seq_h) = timed(1)?;
    let (pool_s, pool_h) = timed(POOL_THREADS)?;
    let speedup = seq_s / pool_s.max(1e-12);
    let identical = seq_h.bits_eq(&pool_h);
    let seq_loss = seq_h.iters.last().map(|r| r.train_loss).unwrap_or(f64::NAN);
    let pool_loss = pool_h.iters.last().map(|r| r.train_loss).unwrap_or(f64::NAN);

    let mut out = String::from(
        "=== Engine-pool wall clock: sequential vs pooled sim driver ===\n",
    );
    out.push_str(&format!(
        "workload: {} / 16 workers / {} iters\n",
        s.model, s.train.iters
    ));
    out.push_str(&format!("  threads=1 (baseline)  : {seq_s:.3}s wall\n"));
    out.push_str(&format!("  threads={POOL_THREADS} (pooled)    : {pool_s:.3}s wall\n"));
    out.push_str(&format!(
        "  speedup               : {speedup:.2}x  (hardware parallelism: {})\n",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    ));
    out.push_str(&format!(
        "  bit-identical history : {identical}  (final train loss {seq_loss:.6} vs {pool_loss:.6})\n"
    ));

    let mut j = Json::obj();
    j.set("bench", "pool_speedup".into())
        .set("model", s.model.as_str().into())
        .set("workers", s.workers.into())
        .set("iters", s.train.iters.into())
        .set("quick", quick.into())
        .set("threads_pool", POOL_THREADS.into())
        .set(
            "hardware_parallelism",
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).into(),
        )
        .set("seq_seconds", seq_s.into())
        .set("pool_seconds", pool_s.into())
        .set("speedup", speedup.into())
        .set("bit_identical", identical.into());
    std::fs::create_dir_all(out_dir)?;
    let path = out_dir.join("BENCH_speedup.json");
    std::fs::write(&path, j.to_string())?;
    out.push_str(&format!("(bench JSON -> {})\n", path.display()));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_quick_runs() {
        let mut s = Setup::default();
        s.train_n = 2400;
        s.test_n = 1024;
        let dir = std::env::temp_dir().join("dybw_speedup_test");
        let out = run(&s, &dir, true).unwrap();
        assert!(out.contains("N x K"));
        assert!(out.contains("Engine-pool wall clock"));
        // the perf-trajectory artifact exists and is valid JSON
        let bench = std::fs::read_to_string(dir.join("BENCH_speedup.json")).unwrap();
        let j = crate::util::json::Json::parse(&bench).unwrap();
        assert_eq!(j.get("bench").and_then(|v| v.as_str()), Some("pool_speedup"));
        assert_eq!(j.get("bit_identical").and_then(|v| v.as_bool()), Some(true));
        assert!(j.get("speedup").and_then(|v| v.as_f64()).unwrap() > 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
