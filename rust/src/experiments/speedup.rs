//! Linear-speedup validation (Corollaries 2-3).
//!
//! Theory: with η = √(N/K) the convergence rate is O(1/√(NK) + 1/K), so
//! the number of iterations to reach ε-accuracy scales like 1/N — "linear
//! speedup for convergence". We sweep N, hold everything else fixed
//! (including the TOTAL dataset size, so more workers = more parallel
//! data), and report iterations-to-target and the N·K̃ product, which the
//! theory predicts approximately constant once K is large enough.

use std::path::Path;

use crate::coordinator::setup::Setup;
use crate::coordinator::Algorithm;
use crate::metrics::export;

pub fn run(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    let ns: &[usize] = if quick { &[2, 4] } else { &[2, 4, 6, 8, 12, 16] };
    let iters = if quick { 60 } else { 400 };
    let target = 0.55; // test loss target for the easy LRM task
    let mut out = String::from("=== Linear speedup (Corollary 2/3): iterations to target vs N ===\n");
    out.push_str(&format!(
        "{:>4} | {:>12} {:>10} {:>12} {:>14}\n",
        "N", "iters to", "N x K", "final loss", "mean T(k) (s)"
    ));
    let mut prev_k: Option<usize> = None;
    for &n in ns {
        let mut s = base.clone();
        s.workers = n;
        s.algo = Algorithm::CbDybw;
        s.model = "lrm_d64_c10_b256".into();
        s.train.iters = iters;
        s.train.eval_every = 5;
        // Corollary 2's schedule: η = √(N/K) (clamped for stability).
        s.train.lr0 = (n as f64 / iters as f64).sqrt().min(0.5);
        s.train.lr_decay = 1.0;
        let mut trainer = s.build_sim()?;
        let h = trainer.run()?;
        export::write_csv(&h, out_dir, &format!("speedup.n{n}"))?;
        let k_target = h.iters_to_test_loss(target);
        let final_loss = h.final_eval().map(|e| e.test_loss).unwrap_or(f64::NAN);
        out.push_str(&format!(
            "{:>4} | {:>12} {:>10} {:>12.4} {:>14.3}\n",
            n,
            k_target.map(|k| k.to_string()).unwrap_or_else(|| "n/a".into()),
            k_target.map(|k| (n * k).to_string()).unwrap_or_else(|| "-".into()),
            final_loss,
            h.mean_iter_duration()
        ));
        if let (Some(prev), Some(cur)) = (prev_k, k_target) {
            // monotone non-increasing iterations with more workers
            // (allow slack for stochastic wiggle)
            if cur as f64 > prev as f64 * 1.5 {
                out.push_str(&format!(
                    "  !! speedup violated between N and previous row ({prev} -> {cur})\n"
                ));
            }
        }
        prev_k = k_target.or(prev_k);
    }
    out.push_str("(theory: K_eps ~ 1/(eps^2 N); N x K approximately constant)\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_quick_runs() {
        let mut s = Setup::default();
        s.train_n = 2400;
        s.test_n = 1024;
        let dir = std::env::temp_dir().join("dybw_speedup_test");
        let out = run(&s, &dir, true).unwrap();
        assert!(out.contains("N x K"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
