//! Linear-speedup validation (Corollaries 2-3) + engine-pool wall clock.
//!
//! Theory: with η = √(N/K) the convergence rate is O(1/√(NK) + 1/K), so
//! the number of iterations to reach ε-accuracy scales like 1/N — "linear
//! speedup for convergence". We sweep N, hold everything else fixed
//! (including the TOTAL dataset size, so more workers = more parallel
//! data), and report iterations-to-target and the N·K̃ product, which the
//! theory predicts approximately constant once K is large enough.
//!
//! The second section measures the *system* speedup delivered by the
//! [`EnginePool`](crate::engine::EnginePool) refactor: identical 16-worker
//! 2NN training (bit-identical histories), sequential (1 lane) vs pooled
//! (4 lanes), plus the eq. (6) mixing phase in isolation (sequential loop
//! vs pooled row fan-out at figure-scale dimension), plus the DES event
//! core's throughput (events/second on a 100k-worker timing-only ring),
//! plus the telemetry overhead of a live metric registry on the DES
//! (gated at an absolute < 2% ceiling, with bit-identical stats),
//! all reported as wall-clock seconds and written to
//! `BENCH_speedup.json` so CI can track the perf trajectory. [`gate`]
//! turns that JSON into a regression gate against a committed baseline.

use std::path::Path;
use std::time::Instant;

use crate::consensus::mixing::ParamBuffers;
use crate::consensus::ConsensusMatrix;
use crate::coordinator::setup::Setup;
use crate::coordinator::Algorithm;
use crate::data::synthetic::{gaussian_mixture, gaussian_mixture_pooled, MixtureSpec};
use crate::engine::EnginePool;
use crate::metrics::export;
use crate::metrics::RunHistory;
use crate::util::json::Json;
use crate::util::rng::Rng;

pub fn run(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    let ns: &[usize] = if quick { &[2, 4] } else { &[2, 4, 6, 8, 12, 16] };
    let iters = if quick { 60 } else { 400 };
    let target = 0.55; // test loss target for the easy LRM task
    let mut out =
        String::from("=== Linear speedup (Corollary 2/3): iterations to target vs N ===\n");
    out.push_str(&format!(
        "{:>4} | {:>12} {:>10} {:>12} {:>14}\n",
        "N", "iters to", "N x K", "final loss", "mean T(k) (s)"
    ));
    // One concurrent cell per N (the sweep cells are independent runs);
    // rows and the monotonicity check render in sweep order afterwards.
    let jobs: Vec<_> = ns
        .iter()
        .map(|&n| {
            let mut s = super::cell_setup(base);
            s.workers = n;
            s.algo = Algorithm::CbDybw;
            s.model = "lrm_d64_c10_b256".into();
            s.train.iters = iters;
            s.train.eval_every = 5;
            // Corollary 2's schedule: η = √(N/K) (clamped for stability).
            s.train.lr0 = (n as f64 / iters as f64).sqrt().min(0.5);
            s.train.lr_decay = 1.0;
            move || -> anyhow::Result<RunHistory> {
                let mut trainer = s.build_sim()?;
                let h = trainer.run()?;
                export::write_csv(&h, out_dir, &format!("speedup.n{n}"))?;
                Ok(h)
            }
        })
        .collect();
    let hists = super::run_cells(jobs)?;
    let mut prev_k: Option<usize> = None;
    for (&n, h) in ns.iter().zip(&hists) {
        let k_target = h.iters_to_test_loss(target);
        let final_loss = h.final_eval().map(|e| e.test_loss).unwrap_or(f64::NAN);
        out.push_str(&format!(
            "{:>4} | {:>12} {:>10} {:>12.4} {:>14.3}\n",
            n,
            k_target.map(|k| k.to_string()).unwrap_or_else(|| "n/a".into()),
            k_target.map(|k| (n * k).to_string()).unwrap_or_else(|| "-".into()),
            final_loss,
            h.mean_iter_duration()
        ));
        if let (Some(prev), Some(cur)) = (prev_k, k_target) {
            // monotone non-increasing iterations with more workers
            // (allow slack for stochastic wiggle)
            if cur as f64 > prev as f64 * 1.5 {
                out.push_str(&format!(
                    "  !! speedup violated between N and previous row ({prev} -> {cur})\n"
                ));
            }
        }
        prev_k = k_target.or(prev_k);
    }
    out.push_str("(theory: K_eps ~ 1/(eps^2 N); N x K approximately constant)\n");
    out.push('\n');
    out.push_str(&pool_wall_clock(base, out_dir, quick)?);
    Ok(out)
}

/// Sequential-vs-pooled sim-driver wall clock on the 16-worker 2NN
/// workload. Same seed -> bit-identical histories; only the clock moves.
pub fn pool_wall_clock(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    const POOL_THREADS: usize = 4;
    let mut s = base.clone();
    s.workers = 16;
    s.algo = Algorithm::CbDybw;
    s.model = "mlp2_d64_h256_c10_b256".into();
    s.train_n = if quick { 4_096 } else { 16_384 };
    s.test_n = 512;
    s.train.iters = if quick { 3 } else { 20 };
    s.train.eval_every = 0;

    let timed = |threads: usize| -> anyhow::Result<(f64, crate::metrics::RunHistory)> {
        let mut s2 = s.clone();
        s2.threads = threads;
        let mut trainer = s2.build_sim()?;
        let t0 = Instant::now();
        let h = trainer.run()?;
        Ok((t0.elapsed().as_secs_f64(), h))
    };
    // Best-of-3 wall clock in release (where CI gates on the ratio):
    // repetitions are bit-identical (fresh trainer, same seed — enforced),
    // only the clock varies, and min rejects shared-runner noise. Debug
    // builds (the plain `cargo test` path) take one sample — the numbers
    // are not gated there and the naive-loop repetitions would be slow.
    let reps = if cfg!(debug_assertions) { 1 } else { 3 };
    let best = |threads: usize| -> anyhow::Result<(f64, crate::metrics::RunHistory)> {
        let (mut best_s, h) = timed(threads)?;
        for _ in 1..reps {
            let (s2, h2) = timed(threads)?;
            anyhow::ensure!(h.bits_eq(&h2), "repeated speedup runs diverged (nondeterminism)");
            best_s = best_s.min(s2);
        }
        Ok((best_s, h))
    };
    let (seq_s, seq_h) = best(1)?;
    let (pool_s, pool_h) = best(POOL_THREADS)?;
    let speedup = seq_s / pool_s.max(1e-12);
    let identical = seq_h.bits_eq(&pool_h);
    let seq_loss = seq_h.iters.last().map(|r| r.train_loss).unwrap_or(f64::NAN);
    let pool_loss = pool_h.iters.last().map(|r| r.train_loss).unwrap_or(f64::NAN);

    let mut out = String::from(
        "=== Engine-pool wall clock: sequential vs pooled sim driver ===\n",
    );
    out.push_str(&format!(
        "workload: {} / 16 workers / {} iters\n",
        s.model, s.train.iters
    ));
    out.push_str(&format!("  threads=1 (baseline)  : {seq_s:.3}s wall\n"));
    out.push_str(&format!("  threads={POOL_THREADS} (pooled)    : {pool_s:.3}s wall\n"));
    out.push_str(&format!(
        "  speedup               : {speedup:.2}x  (hardware parallelism: {})\n",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    ));
    out.push_str(&format!(
        "  bit-identical history : {identical}  (final train loss {seq_loss:.6} vs {pool_loss:.6})\n"
    ));

    let mix = mix_phase(quick)?;
    out.push_str(&mix.report());

    let dp = data_phase(base, quick)?;
    out.push_str(&dp.report());

    let des = des_phase(quick)?;
    out.push_str(&des.report());

    let op = obs_phase(quick)?;
    out.push_str(&op.report());

    let mut j = Json::obj();
    j.set("bench", "pool_speedup".into())
        .set("model", s.model.as_str().into())
        .set("workers", s.workers.into())
        .set("iters", s.train.iters.into())
        .set("quick", quick.into())
        .set("threads_pool", POOL_THREADS.into())
        .set(
            "hardware_parallelism",
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).into(),
        )
        .set("seq_seconds", seq_s.into())
        .set("pool_seconds", pool_s.into())
        .set("speedup", speedup.into())
        .set("bit_identical", identical.into())
        .set("mix_workers", mix.n.into())
        .set("mix_dim", mix.dim.into())
        .set("mix_rounds", mix.rounds.into())
        .set("mix_threads", mix.threads.into())
        .set("mix_seq_seconds", mix.seq_s.into())
        .set("mix_pool_seconds", mix.pool_s.into())
        .set("mix_speedup", mix.speedup.into())
        .set("mix_bit_identical", mix.identical.into())
        .set("data_synth_n", dp.synth_n.into())
        .set("data_synth_dim", dp.synth_dim.into())
        .set("data_synth_threads", dp.threads.into())
        .set("data_synth_seq_seconds", dp.synth_seq_s.into())
        .set("data_synth_pool_seconds", dp.synth_pool_s.into())
        .set("data_synth_speedup", dp.synth_speedup().into())
        .set("data_synth_bit_identical", dp.synth_identical.into())
        .set("data_prefetch_workers", dp.pf_workers.into())
        .set("data_prefetch_iters", dp.pf_iters.into())
        .set("data_prefetch_off_seconds", dp.pf_off_s.into())
        .set("data_prefetch_on_seconds", dp.pf_on_s.into())
        .set("data_prefetch_speedup", dp.pf_speedup().into())
        .set("data_prefetch_bit_identical", dp.pf_identical.into())
        .set("des_workers", des.workers.into())
        .set("des_iters", des.iters.into())
        .set("des_events", (des.events as i64).into())
        .set("des_seconds", des.seconds.into())
        .set("des_mevents_per_sec", des.mevents_per_sec().into())
        .set("obs_workers", op.workers.into())
        .set("obs_iters", op.iters.into())
        .set("obs_off_seconds", op.off_s.into())
        .set("obs_on_seconds", op.on_s.into())
        .set("obs_overhead_ratio", op.ratio().into())
        .set("obs_ceiling", op.ceiling.into())
        .set("obs_bit_identical", op.identical.into());
    std::fs::create_dir_all(out_dir)?;
    let path = out_dir.join("BENCH_speedup.json");
    std::fs::write(&path, j.to_string())?;
    out.push_str(&format!("(bench JSON -> {})\n", path.display()));
    Ok(out)
}

/// Result of the mix-phase sequential-vs-pooled measurement.
struct MixPhase {
    n: usize,
    dim: usize,
    rounds: usize,
    threads: usize,
    seq_s: f64,
    pool_s: f64,
    speedup: f64,
    identical: bool,
}

impl MixPhase {
    fn report(&self) -> String {
        let mut out =
            String::from("=== Mixing-phase wall clock: sequential vs pooled eq. (6) ===\n");
        out.push_str(&format!(
            "workload: {} workers x {} params x {} rounds (Metropolis, full participation)\n",
            self.n, self.dim, self.rounds
        ));
        out.push_str(&format!("  sequential loop       : {:.3}s wall\n", self.seq_s));
        out.push_str(&format!(
            "  pooled ({} lanes)      : {:.3}s wall\n",
            self.threads, self.pool_s
        ));
        out.push_str(&format!("  speedup               : {:.2}x\n", self.speedup));
        out.push_str(&format!("  bit-identical params  : {}\n", self.identical));
        out
    }
}

/// Time `rounds` eq. (6) mixing rounds at figure-scale dimension, once
/// through the sequential loop and once fanned over a 4-lane pool, and
/// verify the two parameter states match bit for bit.
fn mix_phase(quick: bool) -> anyhow::Result<MixPhase> {
    const POOL_THREADS: usize = 4;
    let n = 16usize;
    let dim = if quick { 262_144 } else { 1_048_576 };
    let rounds = if quick { 12 } else { 40 };
    let mut rng = Rng::new(17);
    let g = crate::graph::topology::random_connected(n, 0.4, &mut rng);
    let pm = ConsensusMatrix::metropolis_full(&g);
    let init: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
        .collect();

    // Best-of-3 wall clock, same rationale as `pool_wall_clock`: every
    // repetition is bit-identical (same init, same P), only the clock
    // varies, and min rejects shared-runner noise.
    let run_rounds = |pool: Option<&EnginePool>| -> anyhow::Result<(f64, ParamBuffers)> {
        let mut bufs = ParamBuffers::from_initial(init.clone());
        let t0 = Instant::now();
        for _ in 0..rounds {
            match pool {
                Some(pool) => bufs.mix_pooled(&pm, pool)?,
                None => bufs.mix(&pm),
            }
        }
        Ok((t0.elapsed().as_secs_f64(), bufs))
    };
    let reps = if cfg!(debug_assertions) { 1 } else { 3 };
    let best = |pool: Option<&EnginePool>| -> anyhow::Result<(f64, ParamBuffers)> {
        let (mut best_s, bufs) = run_rounds(pool)?;
        for _ in 1..reps {
            let (s2, b2) = run_rounds(pool)?;
            for j in 0..bufs.n() {
                anyhow::ensure!(
                    bufs.get(j).iter().zip(b2.get(j)).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "repeated mix runs diverged (nondeterminism)"
                );
            }
            best_s = best_s.min(s2);
        }
        Ok((best_s, bufs))
    };
    let (seq_s, seq) = best(None)?;
    let pool = EnginePool::tasks_only(POOL_THREADS)?;
    let (pool_s, par) = best(Some(&pool))?;

    let identical = (0..n).all(|j| {
        seq.get(j)
            .iter()
            .zip(par.get(j))
            .all(|(a, b)| a.to_bits() == b.to_bits())
    });
    Ok(MixPhase {
        n,
        dim,
        rounds,
        threads: POOL_THREADS,
        seq_s,
        pool_s,
        speedup: seq_s / pool_s.max(1e-12),
        identical,
    })
}

/// Result of the data-phase measurements: pooled-vs-sequential dataset
/// synthesis, and the sim driver with batch prefetch off vs on.
struct DataPhase {
    synth_n: usize,
    synth_dim: usize,
    threads: usize,
    synth_seq_s: f64,
    synth_pool_s: f64,
    synth_identical: bool,
    pf_workers: usize,
    pf_iters: usize,
    pf_off_s: f64,
    pf_on_s: f64,
    pf_identical: bool,
}

impl DataPhase {
    fn synth_speedup(&self) -> f64 {
        self.synth_seq_s / self.synth_pool_s.max(1e-12)
    }

    fn pf_speedup(&self) -> f64 {
        self.pf_off_s / self.pf_on_s.max(1e-12)
    }

    fn report(&self) -> String {
        let mut out =
            String::from("=== Data-phase wall clock: pooled synthesis + batch prefetch ===\n");
        out.push_str(&format!(
            "synthesis: gaussian mixture {} x {} (seq vs {} lanes)\n",
            self.synth_n, self.synth_dim, self.threads
        ));
        out.push_str(&format!("  sequential generator  : {:.3}s wall\n", self.synth_seq_s));
        out.push_str(&format!("  pooled generator      : {:.3}s wall\n", self.synth_pool_s));
        out.push_str(&format!("  speedup               : {:.2}x\n", self.synth_speedup()));
        out.push_str(&format!("  bit-identical data    : {}\n", self.synth_identical));
        out.push_str(&format!(
            "prefetch: {} workers x {} iters (batches drawn between vs during fan-outs)\n",
            self.pf_workers, self.pf_iters
        ));
        out.push_str(&format!("  prefetch off          : {:.3}s wall\n", self.pf_off_s));
        out.push_str(&format!("  prefetch on           : {:.3}s wall\n", self.pf_on_s));
        out.push_str(&format!("  speedup               : {:.2}x\n", self.pf_speedup()));
        out.push_str(&format!("  bit-identical history : {}\n", self.pf_identical));
        out
    }
}

/// Measure the data path: (a) the gaussian-mixture generator, sequential
/// vs fanned over a 4-lane pool, asserting the datasets AND the
/// post-generation RNG states match bit for bit; (b) the 16-worker sim
/// driver with batch prefetch off vs on, asserting bit-identical
/// histories. Best-of-3 in release, single-sample in debug (same
/// rationale as `pool_wall_clock`).
fn data_phase(base: &Setup, quick: bool) -> anyhow::Result<DataPhase> {
    const POOL_THREADS: usize = 4;
    let synth_dim = 64usize;
    let synth_n = if cfg!(debug_assertions) {
        40_000
    } else if quick {
        120_000
    } else {
        480_000
    };
    let spec = MixtureSpec::mnist_like(synth_dim, synth_n);
    let pool = EnginePool::tasks_only(POOL_THREADS)?;
    let reps = if cfg!(debug_assertions) { 1 } else { 3 };

    let seq_run = || -> (f64, crate::data::Dataset, Rng) {
        let mut rng = Rng::new(23);
        let t0 = Instant::now();
        let d = gaussian_mixture(&spec, &mut rng);
        (t0.elapsed().as_secs_f64(), d, rng)
    };
    let pool_run = |pool: &EnginePool| -> anyhow::Result<(f64, crate::data::Dataset, Rng)> {
        let mut rng = Rng::new(23);
        let t0 = Instant::now();
        let d = gaussian_mixture_pooled(&spec, &mut rng, pool)?;
        Ok((t0.elapsed().as_secs_f64(), d, rng))
    };
    let (mut synth_seq_s, seq_d, mut seq_rng) = seq_run();
    for _ in 1..reps {
        let (s2, ..) = seq_run();
        synth_seq_s = synth_seq_s.min(s2);
    }
    let (mut synth_pool_s, pool_d, mut pool_rng) = pool_run(&pool)?;
    for _ in 1..reps {
        let (s2, ..) = pool_run(&pool)?;
        synth_pool_s = synth_pool_s.min(s2);
    }
    let synth_identical = seq_d.y == pool_d.y
        && seq_d.x.len() == pool_d.x.len()
        && seq_d.x.iter().zip(&pool_d.x).all(|(a, b)| a.to_bits() == b.to_bits())
        && (0..4).all(|_| seq_rng.next_u64() == pool_rng.next_u64());
    drop((seq_d, pool_d));

    let mut s = base.clone();
    s.workers = 16;
    s.algo = Algorithm::CbDybw;
    s.model = "mlp2_d64_h256_c10_b256".into();
    s.train_n = if quick { 4_096 } else { 16_384 };
    s.test_n = 512;
    s.train.iters = if cfg!(debug_assertions) {
        2
    } else if quick {
        4
    } else {
        20
    };
    s.train.eval_every = 0;
    s.threads = POOL_THREADS;
    let timed = |prefetch: bool| -> anyhow::Result<(f64, RunHistory)> {
        let mut s2 = s.clone();
        s2.train.prefetch = prefetch;
        let mut trainer = s2.build_sim()?;
        let t0 = Instant::now();
        let h = trainer.run()?;
        Ok((t0.elapsed().as_secs_f64(), h))
    };
    let best = |prefetch: bool| -> anyhow::Result<(f64, RunHistory)> {
        let (mut best_s, h) = timed(prefetch)?;
        for _ in 1..reps {
            let (s2, h2) = timed(prefetch)?;
            anyhow::ensure!(h.bits_eq(&h2), "repeated prefetch runs diverged (nondeterminism)");
            best_s = best_s.min(s2);
        }
        Ok((best_s, h))
    };
    let (pf_off_s, off_h) = best(false)?;
    let (pf_on_s, on_h) = best(true)?;
    let pf_identical = off_h.bits_eq(&on_h);

    Ok(DataPhase {
        synth_n,
        synth_dim,
        threads: POOL_THREADS,
        synth_seq_s,
        synth_pool_s,
        synth_identical,
        pf_workers: s.workers,
        pf_iters: s.train.iters,
        pf_off_s,
        pf_on_s,
        pf_identical,
    })
}

/// Result of the DES-throughput measurement: events/second through the
/// calendar event queue + CSR worker state, timing-only, at the scale
/// the CI gate tracks.
struct DesPhase {
    workers: usize,
    iters: usize,
    events: u64,
    seconds: f64,
}

impl DesPhase {
    fn mevents_per_sec(&self) -> f64 {
        self.events as f64 / self.seconds.max(1e-12) / 1e6
    }

    fn report(&self) -> String {
        let mut out = String::from("=== DES throughput: calendar event queue at scale ===\n");
        out.push_str(&format!(
            "workload: {}-worker ring x {} iters/worker, dybw policy, timing-only\n",
            self.workers, self.iters
        ));
        out.push_str(&format!("  events                : {}\n", self.events));
        out.push_str(&format!("  wall clock            : {:.3}s (best rep)\n", self.seconds));
        out.push_str(&format!(
            "  throughput            : {:.2}M events/s wall-clock\n",
            self.mevents_per_sec()
        ));
        out
    }
}

/// One timing-only DES run at gate scale (100k-worker ring in the quick
/// CI configuration, 1M in the full run, small in debug), best-of-reps.
/// Compute/link times are pure functions of their coordinates, so
/// repetitions must agree exactly — the event count and the makespan
/// bits are asserted across reps (determinism is part of the contract,
/// and the min over reps rejects shared-runner noise). The ring is
/// built outside the timed section: the number tracks the event core,
/// not graph construction.
fn des_phase(quick: bool) -> anyhow::Result<DesPhase> {
    use crate::des::{ClusterSim, ComputeTimes, NoHooks, WaitPolicy};
    use crate::straggler::link::LinkModel;
    use crate::straggler::Dist;
    let (workers, iters) = if cfg!(debug_assertions) {
        (10_000, 3)
    } else if quick {
        (100_000, 5)
    } else {
        (1_000_000, 3)
    };
    let reps = if cfg!(debug_assertions) { 1 } else { 3 };
    let times = ComputeTimes::PerWorker {
        dist: Dist::ShiftedExp { base: 0.08, rate: 25.0 },
        scale: vec![1.0; workers],
        seed: 11,
    };
    let link = LinkModel::new(0.002, Some(Dist::ShiftedExp { base: 0.0, rate: 800.0 }), 12);
    let one = || -> anyhow::Result<(f64, u64, f64)> {
        let mut sim = ClusterSim::new(
            crate::graph::topology::ring(workers),
            WaitPolicy::Dybw,
            iters,
            times.clone(),
            link.clone(),
        )?;
        let t0 = Instant::now();
        let stats = sim.run(&mut NoHooks)?;
        Ok((t0.elapsed().as_secs_f64(), stats.events, stats.makespan))
    };
    let (mut best_s, events, makespan) = one()?;
    for _ in 1..reps {
        let (s2, e2, m2) = one()?;
        anyhow::ensure!(
            e2 == events && m2.to_bits() == makespan.to_bits(),
            "repeated DES runs diverged (nondeterminism)"
        );
        best_s = best_s.min(s2);
    }
    Ok(DesPhase { workers, iters, events, seconds: best_s })
}

/// Result of the telemetry-overhead measurement: the 10k-worker DES run
/// with a registry-only observer attached vs with none, same seeds.
struct ObsPhase {
    workers: usize,
    iters: usize,
    off_s: f64,
    on_s: f64,
    /// Gate ceiling carried in the artifact: release builds write the
    /// instrumentation contract's 1.02 (< 2% with the registry live);
    /// debug builds, whose wall clocks are not trustworthy at percent
    /// precision, write a loose ceiling so the self-gate stays stable.
    ceiling: f64,
    identical: bool,
}

impl ObsPhase {
    fn ratio(&self) -> f64 {
        self.on_s / self.off_s.max(1e-12)
    }

    fn report(&self) -> String {
        let mut out =
            String::from("=== Telemetry overhead: DES with registry-only observer ===\n");
        out.push_str(&format!(
            "workload: {}-worker ring x {} iters/worker, dybw policy, timing-only\n",
            self.workers, self.iters
        ));
        out.push_str(&format!("  registry off          : {:.3}s wall (best rep)\n", self.off_s));
        out.push_str(&format!("  registry on           : {:.3}s wall (best rep)\n", self.on_s));
        out.push_str(&format!(
            "  overhead ratio        : {:.4}x (gate ceiling {:.2}x)\n",
            self.ratio(),
            self.ceiling
        ));
        out.push_str(&format!("  bit-identical stats   : {}\n", self.identical));
        out
    }
}

/// Measure what the metric registry costs the DES hot loop: the same
/// timing-only ring run with `set_obs(None)` and with a registry-only
/// observer (histograms + counters live, trace sink off — the shape the
/// `--obs-dir`-without-trace-pressure contract gates). Best-of-reps on
/// both sides, and the event count plus makespan bits must agree across
/// ALL runs — telemetry reads clocks, never the RNG, so an observed run
/// is bit-identical to an unobserved one by construction; this asserts
/// the invariant at gate scale.
fn obs_phase(_quick: bool) -> anyhow::Result<ObsPhase> {
    use crate::des::{ClusterSim, ComputeTimes, NoHooks, WaitPolicy};
    use crate::straggler::link::LinkModel;
    use crate::straggler::Dist;
    let (workers, iters) = if cfg!(debug_assertions) { (10_000, 3) } else { (10_000, 10) };
    let reps = if cfg!(debug_assertions) { 3 } else { 5 };
    let times = ComputeTimes::PerWorker {
        dist: Dist::ShiftedExp { base: 0.08, rate: 25.0 },
        scale: vec![1.0; workers],
        seed: 11,
    };
    let link = LinkModel::new(0.002, Some(Dist::ShiftedExp { base: 0.0, rate: 800.0 }), 12);
    let one = |observed: bool| -> anyhow::Result<(f64, u64, f64)> {
        let mut sim = ClusterSim::new(
            crate::graph::topology::ring(workers),
            WaitPolicy::Dybw,
            iters,
            times.clone(),
            link.clone(),
        )?;
        sim.set_obs(observed.then(crate::obs::Obs::registry_only));
        let t0 = Instant::now();
        let stats = sim.run(&mut NoHooks)?;
        Ok((t0.elapsed().as_secs_f64(), stats.events, stats.makespan))
    };
    let best = |observed: bool| -> anyhow::Result<(f64, u64, f64)> {
        let (mut best_s, events, makespan) = one(observed)?;
        for _ in 1..reps {
            let (s2, e2, m2) = one(observed)?;
            anyhow::ensure!(
                e2 == events && m2.to_bits() == makespan.to_bits(),
                "repeated DES runs diverged (nondeterminism)"
            );
            best_s = best_s.min(s2);
        }
        Ok((best_s, events, makespan))
    };
    let (off_s, off_e, off_m) = best(false)?;
    let (on_s, on_e, on_m) = best(true)?;
    Ok(ObsPhase {
        workers,
        iters,
        off_s,
        on_s,
        ceiling: if cfg!(debug_assertions) { 1.5 } else { 1.02 },
        identical: off_e == on_e && off_m.to_bits() == on_m.to_bits(),
    })
}

/// CI perf-trajectory gate: compare a freshly measured `BENCH_speedup.json`
/// against the committed baseline. Fails when pooled execution stopped
/// being bit-identical (correctness regression — never tolerated) or when
/// either measured speedup (end-to-end pooled training, or the mixing
/// phase in isolation) dropped below `tolerance` x the baseline value
/// (perf regression beyond noise). Returns the comparison report on pass.
pub fn gate(current: &Path, baseline: &Path, tolerance: f64) -> anyhow::Result<String> {
    anyhow::ensure!(
        (0.0..=1.0).contains(&tolerance),
        "tolerance must be in [0, 1] (got {tolerance})"
    );
    let load = |path: &Path| -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("bad JSON in {}: {e}", path.display()))
    };
    let cur = load(current)?;
    let base = load(baseline)?;

    let mut out = String::from("=== bench gate: current vs committed baseline ===\n");
    let mut failures: Vec<String> = Vec::new();

    // Speedups are only comparable on the same workload: when both files
    // carry a config key, it must match (a baseline written before a
    // workload retune must be refreshed, not silently compared against).
    for key in [
        "quick",
        "threads_pool",
        "workers",
        "iters",
        "mix_workers",
        "mix_dim",
        "mix_rounds",
        "mix_threads",
        "data_synth_n",
        "data_synth_dim",
        "data_synth_threads",
        "data_prefetch_workers",
        "data_prefetch_iters",
        "des_workers",
        "des_iters",
        "obs_workers",
        "obs_iters",
    ] {
        if let (Some(c), Some(b)) = (cur.get(key), base.get(key)) {
            let (cs, bs) = (c.to_string(), b.to_string());
            anyhow::ensure!(
                cs == bs,
                "workload mismatch on '{key}' ({cs} vs baseline {bs}) — the committed \
                 baseline is stale; refresh it (bench gate --refresh)"
            );
        }
    }

    // Core bit-identity flags are required; the data_phase flags (newer
    // schema) are gated whenever the CURRENT file carries them — current
    // is always freshly measured, so only core absence is malformed.
    for key in ["bit_identical", "mix_bit_identical"] {
        // A missing key is a malformed/stale input, not a determinism
        // regression — report it as such.
        let ok = cur
            .get(key)
            .and_then(|v| v.as_bool())
            .ok_or_else(|| anyhow::anyhow!("{} missing '{key}'", current.display()))?;
        out.push_str(&format!("  {key:<26}: {ok}\n"));
        if !ok {
            failures.push(format!("{key} is false — pooled execution diverged"));
        }
    }
    for key in ["data_synth_bit_identical", "data_prefetch_bit_identical"] {
        match cur.get(key).and_then(|v| v.as_bool()) {
            Some(ok) => {
                out.push_str(&format!("  {key:<26}: {ok}\n"));
                if !ok {
                    failures.push(format!("{key} is false — pooled execution diverged"));
                }
            }
            None => out.push_str(&format!("  {key:<26}: (not measured)\n")),
        }
    }
    // Core speedups are required on both sides; the data_phase speedups
    // gate only when the baseline carries a floor for them (schema
    // evolution: baselines committed before this section exist, and must
    // keep gating the pool/mix sections instead of erroring).
    for key in ["speedup", "mix_speedup"] {
        let c = cur
            .get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("{} missing '{key}'", current.display()))?;
        let b = base
            .get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("{} missing '{key}'", baseline.display()))?;
        let floor = b * tolerance;
        let ok = c >= floor;
        out.push_str(&format!(
            "  {key:<26}: {c:.3}x vs baseline {b:.3}x (floor {floor:.3}x) {}\n",
            if ok { "ok" } else { "REGRESSION" }
        ));
        if !ok {
            failures.push(format!(
                "{key} {c:.3}x fell below {floor:.3}x ({tolerance} x baseline {b:.3}x)"
            ));
        }
    }
    for key in ["data_synth_speedup", "data_prefetch_speedup"] {
        let c = cur.get(key).and_then(|v| v.as_f64());
        let b = base.get(key).and_then(|v| v.as_f64());
        match (c, b) {
            (Some(c), Some(b)) => {
                let floor = b * tolerance;
                let ok = c >= floor;
                out.push_str(&format!(
                    "  {key:<26}: {c:.3}x vs baseline {b:.3}x (floor {floor:.3}x) {}\n",
                    if ok { "ok" } else { "REGRESSION" }
                ));
                if !ok {
                    failures.push(format!(
                        "{key} {c:.3}x fell below {floor:.3}x ({tolerance} x baseline {b:.3}x)"
                    ));
                }
            }
            (Some(c), None) => {
                out.push_str(&format!("  {key:<26}: {c:.3}x (no baseline floor; not gated)\n"));
            }
            (None, Some(_)) => {
                failures.push(format!(
                    "{key} missing from current — stale bench artifact predates the \
                     data_phase section"
                ));
            }
            (None, None) => {}
        }
    }
    // DES throughput (absolute M events/s, not a ratio) gates with the
    // same schema-evolution rules as the data_phase sections: a floor
    // only when the baseline carries one, and a current missing the
    // section against a baseline that has it is a stale artifact.
    {
        let key = "des_mevents_per_sec";
        match (
            cur.get(key).and_then(|v| v.as_f64()),
            base.get(key).and_then(|v| v.as_f64()),
        ) {
            (Some(c), Some(b)) => {
                let floor = b * tolerance;
                let ok = c >= floor;
                out.push_str(&format!(
                    "  {key:<26}: {c:.3} vs baseline {b:.3} (floor {floor:.3} M events/s) {}\n",
                    if ok { "ok" } else { "REGRESSION" }
                ));
                if !ok {
                    failures.push(format!(
                        "{key} {c:.3} fell below {floor:.3} ({tolerance} x baseline {b:.3})"
                    ));
                }
            }
            (Some(c), None) => {
                out.push_str(&format!("  {key:<26}: {c:.3} (no baseline floor; not gated)\n"));
            }
            (None, Some(_)) => {
                failures.push(format!(
                    "{key} missing from current — stale bench artifact predates the des section"
                ));
            }
            (None, None) => {}
        }
    }
    // Telemetry overhead: an ABSOLUTE ceiling on the registry-on vs
    // registry-off DES wall-clock ratio, not a baseline-relative floor —
    // the instrumentation contract ("a live registry costs < 2%") does
    // not depend on the hardware, so the ceiling travels in the current
    // artifact itself (`obs_ceiling`; 1.02 from release measurements).
    // Bit identity of the observed run is required whenever the section
    // was measured; schema evolution mirrors the des section.
    {
        let key = "obs_overhead_ratio";
        match (
            cur.get(key).and_then(|v| v.as_f64()),
            base.get(key).and_then(|v| v.as_f64()),
        ) {
            (Some(c), _) => {
                let ceiling = cur.get("obs_ceiling").and_then(|v| v.as_f64()).unwrap_or(1.02);
                let ok = c <= ceiling;
                out.push_str(&format!(
                    "  {key:<26}: {c:.4}x (ceiling {ceiling:.2}x) {}\n",
                    if ok { "ok" } else { "REGRESSION" }
                ));
                if !ok {
                    failures.push(format!(
                        "{key} {c:.4}x exceeds the {ceiling:.2}x ceiling — telemetry got \
                         too expensive for the DES hot loop"
                    ));
                }
                match cur.get("obs_bit_identical").and_then(|v| v.as_bool()) {
                    Some(true) => out.push_str("  obs_bit_identical         : true\n"),
                    Some(false) => failures.push(
                        "obs_bit_identical is false — attaching telemetry perturbed the DES"
                            .to_string(),
                    ),
                    None => failures.push(format!(
                        "{} carries '{key}' but no 'obs_bit_identical'",
                        current.display()
                    )),
                }
            }
            (None, Some(_)) => {
                failures.push(format!(
                    "{key} missing from current — stale bench artifact predates the obs section"
                ));
            }
            (None, None) => {}
        }
    }
    if !failures.is_empty() {
        anyhow::bail!("{out}\nperf gate FAILED:\n  - {}", failures.join("\n  - "));
    }
    out.push_str("perf gate passed.\n");
    Ok(out)
}

/// Install `current` as the committed baseline (re-baselining after an
/// intentional workload retune, or from a CI artifact's numbers — see
/// the hardware-relative note in ROADMAP.md). The gate against the OLD
/// baseline is reported but does not block — that gate failing is
/// precisely when a refresh is needed — while a malformed or
/// non-bit-identical `current` is rejected via a self-gate, so a broken
/// artifact can never become the floor.
pub fn refresh(current: &Path, baseline: &Path, tolerance: f64) -> anyhow::Result<String> {
    let old_gate = gate(current, baseline, tolerance);
    gate(current, current, tolerance)
        .map_err(|e| anyhow::anyhow!("refusing to install current as baseline: {e}"))?;
    std::fs::copy(current, baseline)?;
    let mut out = match old_gate {
        Ok(report) => report,
        Err(e) => format!("{e}\n(gate failed against the OLD baseline)\n"),
    };
    out.push_str(&format!("(baseline refreshed -> {})\n", baseline.display()));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_quick_runs() {
        let mut s = Setup::default();
        s.train_n = 2400;
        s.test_n = 1024;
        let dir = std::env::temp_dir().join("dybw_speedup_test");
        let out = run(&s, &dir, true).unwrap();
        assert!(out.contains("N x K"));
        assert!(out.contains("Engine-pool wall clock"));
        assert!(out.contains("Mixing-phase wall clock"));
        assert!(out.contains("Data-phase wall clock"));
        // the perf-trajectory artifact exists and is valid JSON
        let bench = std::fs::read_to_string(dir.join("BENCH_speedup.json")).unwrap();
        let j = crate::util::json::Json::parse(&bench).unwrap();
        assert_eq!(j.get("bench").and_then(|v| v.as_str()), Some("pool_speedup"));
        assert_eq!(j.get("bit_identical").and_then(|v| v.as_bool()), Some(true));
        assert!(j.get("speedup").and_then(|v| v.as_f64()).unwrap() > 0.0);
        // the mix-phase section is present, bit-identical, and measured
        assert_eq!(j.get("mix_bit_identical").and_then(|v| v.as_bool()), Some(true));
        assert!(j.get("mix_speedup").and_then(|v| v.as_f64()).unwrap() > 0.0);
        assert!(j.get("mix_dim").and_then(|v| v.as_usize()).unwrap() >= 262_144);
        // the data-phase section too: pooled synthesis and prefetch both
        // measured and bit-identical
        assert_eq!(j.get("data_synth_bit_identical").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(j.get("data_prefetch_bit_identical").and_then(|v| v.as_bool()), Some(true));
        assert!(j.get("data_synth_speedup").and_then(|v| v.as_f64()).unwrap() > 0.0);
        assert!(j.get("data_prefetch_speedup").and_then(|v| v.as_f64()).unwrap() > 0.0);
        // the DES-throughput section: events measured and positive
        assert!(out.contains("DES throughput"));
        assert!(j.get("des_events").and_then(|v| v.as_f64()).unwrap() > 0.0);
        assert!(j.get("des_mevents_per_sec").and_then(|v| v.as_f64()).unwrap() > 0.0);
        assert!(j.get("des_workers").and_then(|v| v.as_usize()).unwrap() >= 10_000);
        // the telemetry-overhead section: ratio measured, observed run
        // bit-identical to the unobserved one
        assert!(out.contains("Telemetry overhead"));
        assert!(j.get("obs_overhead_ratio").and_then(|v| v.as_f64()).unwrap() > 0.0);
        assert!(j.get("obs_ceiling").and_then(|v| v.as_f64()).unwrap() >= 1.02);
        assert_eq!(j.get("obs_bit_identical").and_then(|v| v.as_bool()), Some(true));
        // and a self-gate against the fresh numbers passes trivially
        let path = dir.join("BENCH_speedup.json");
        assert!(gate(&path, &path, 0.75).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gate_detects_regressions() {
        let dir = std::env::temp_dir().join("dybw_gate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let write = |name: &str, speedup: f64, mix: f64, ident: bool| {
            let mut j = Json::obj();
            j.set("speedup", speedup.into())
                .set("mix_speedup", mix.into())
                .set("bit_identical", ident.into())
                .set("mix_bit_identical", true.into());
            let p = dir.join(name);
            std::fs::write(&p, j.to_string()).unwrap();
            p
        };
        let base = write("base.json", 2.0, 2.0, true);
        let good = write("good.json", 1.8, 1.9, true);
        let slow = write("slow.json", 1.0, 1.9, true);
        let slow_mix = write("slow_mix.json", 1.9, 1.2, true);
        let broken = write("broken.json", 2.2, 2.2, false);
        assert!(gate(&good, &base, 0.75).is_ok());
        assert!(gate(&slow, &base, 0.75).is_err(), "grad speedup regression must fail");
        assert!(gate(&slow_mix, &base, 0.75).is_err(), "mix speedup regression must fail");
        assert!(gate(&broken, &base, 0.75).is_err(), "bit-identity loss must fail");
        assert!(gate(&good, &base, 1.5).is_err(), "tolerance > 1 is rejected");
        assert!(gate(&dir.join("missing.json"), &base, 0.75).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Write a bench JSON in the NEW schema (core + data_phase sections).
    fn write_full(
        dir: &Path,
        name: &str,
        speedup: f64,
        data_synth: f64,
        data_prefetch: f64,
        bit: bool,
        data_bit: bool,
    ) -> std::path::PathBuf {
        let mut j = Json::obj();
        j.set("speedup", speedup.into())
            .set("mix_speedup", speedup.into())
            .set("bit_identical", bit.into())
            .set("mix_bit_identical", true.into())
            .set("data_synth_speedup", data_synth.into())
            .set("data_prefetch_speedup", data_prefetch.into())
            .set("data_synth_bit_identical", data_bit.into())
            .set("data_prefetch_bit_identical", true.into());
        let p = dir.join(name);
        std::fs::write(&p, j.to_string()).unwrap();
        p
    }

    /// Schema evolution: a baseline committed BEFORE the data_phase
    /// section must keep gating the pool/mix sections (not error), while
    /// the data sections stay ungated until the baseline is refreshed.
    #[test]
    fn gate_old_baseline_without_data_phase_still_gates_core() {
        let dir = std::env::temp_dir().join("dybw_gate_schema_test");
        std::fs::create_dir_all(&dir).unwrap();
        // old-schema baseline: core keys only
        let mut j = Json::obj();
        j.set("speedup", 2.0.into())
            .set("mix_speedup", 2.0.into())
            .set("bit_identical", true.into())
            .set("mix_bit_identical", true.into());
        let base = dir.join("base_old.json");
        std::fs::write(&base, j.to_string()).unwrap();

        let good = write_full(&dir, "cur_good.json", 1.9, 3.0, 1.0, true, true);
        let report = gate(&good, &base, 0.75).unwrap();
        assert!(report.contains("not gated"), "{report}");

        // ...but a core regression (or a data bit-identity loss in the
        // fresh measurement) still fails against the old baseline.
        let slow = write_full(&dir, "cur_slow.json", 1.0, 3.0, 1.0, true, true);
        assert!(gate(&slow, &base, 0.75).is_err(), "core regression must still fail");
        let data_broken = write_full(&dir, "cur_databroken.json", 1.9, 3.0, 1.0, true, false);
        assert!(
            gate(&data_broken, &base, 0.75).is_err(),
            "data bit-identity loss must fail even against an old baseline"
        );

        // reversed evolution: a NEW baseline with data floors rejects an
        // old current that lacks the section (stale artifact).
        let new_base = write_full(&dir, "base_new.json", 2.0, 2.0, 1.0, true, true);
        let mut j = Json::obj();
        j.set("speedup", 2.0.into())
            .set("mix_speedup", 2.0.into())
            .set("bit_identical", true.into())
            .set("mix_bit_identical", true.into());
        let stale = dir.join("cur_stale.json");
        std::fs::write(&stale, j.to_string()).unwrap();
        let err = gate(&stale, &new_base, 0.75).unwrap_err();
        assert!(err.to_string().contains("stale bench artifact"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Schema evolution for the DES section, both directions: a current
    /// with a des number against an old baseline reports but does not
    /// gate; a baseline with a des floor rejects a stale current and
    /// fails a regressed one.
    #[test]
    fn gate_handles_des_section_evolution() {
        let dir = std::env::temp_dir().join("dybw_gate_des_test");
        std::fs::create_dir_all(&dir).unwrap();
        let write_des = |name: &str, des: Option<f64>| {
            let mut j = Json::obj();
            j.set("speedup", 2.0.into())
                .set("mix_speedup", 2.0.into())
                .set("bit_identical", true.into())
                .set("mix_bit_identical", true.into());
            if let Some(d) = des {
                j.set("des_mevents_per_sec", d.into());
            }
            let p = dir.join(name);
            std::fs::write(&p, j.to_string()).unwrap();
            p
        };
        let base_old = write_des("base_old.json", None);
        let cur_with = write_des("cur_with.json", Some(5.0));
        let report = gate(&cur_with, &base_old, 0.75).unwrap();
        assert!(report.contains("no baseline floor"), "{report}");

        let base_new = write_des("base_new.json", Some(4.0));
        assert!(gate(&cur_with, &base_new, 0.75).is_ok());
        let cur_slow = write_des("cur_slow.json", Some(1.0));
        let err = gate(&cur_slow, &base_new, 0.75).unwrap_err().to_string();
        assert!(err.contains("des_mevents_per_sec"), "{err}");
        let cur_stale = write_des("cur_stale.json", None);
        let err = gate(&cur_stale, &base_new, 0.75).unwrap_err().to_string();
        assert!(err.contains("stale bench artifact"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The obs section gates an ABSOLUTE ceiling (the < 2% contract),
    /// carried by the current artifact — no baseline floor involved —
    /// plus the usual stale-current schema-evolution failure.
    #[test]
    fn gate_enforces_obs_overhead_ceiling() {
        let dir = std::env::temp_dir().join("dybw_gate_obs_test");
        std::fs::create_dir_all(&dir).unwrap();
        let write_obs = |name: &str, obs: Option<(f64, bool, Option<f64>)>| {
            let mut j = Json::obj();
            j.set("speedup", 2.0.into())
                .set("mix_speedup", 2.0.into())
                .set("bit_identical", true.into())
                .set("mix_bit_identical", true.into());
            if let Some((ratio, bit, ceiling)) = obs {
                j.set("obs_overhead_ratio", ratio.into())
                    .set("obs_bit_identical", bit.into());
                if let Some(c) = ceiling {
                    j.set("obs_ceiling", c.into());
                }
            }
            let p = dir.join(name);
            std::fs::write(&p, j.to_string()).unwrap();
            p
        };
        let base_old = write_obs("base_old.json", None);
        // under the ceiling, bit-identical: passes even against an old
        // baseline (the ceiling is absolute, no floor is needed)
        let cur_ok = write_obs("cur_ok.json", Some((1.01, true, Some(1.02))));
        let report = gate(&cur_ok, &base_old, 0.75).unwrap();
        assert!(report.contains("obs_overhead_ratio"), "{report}");
        // over the ceiling: fails regardless of baseline
        let cur_hot = write_obs("cur_hot.json", Some((1.10, true, Some(1.02))));
        let err = gate(&cur_hot, &base_old, 0.75).unwrap_err().to_string();
        assert!(err.contains("obs_overhead_ratio"), "{err}");
        // a missing obs_ceiling defaults to the 1.02 contract
        let cur_noceil = write_obs("cur_noceil.json", Some((1.10, true, None)));
        assert!(gate(&cur_noceil, &base_old, 0.75).is_err());
        // telemetry perturbing the run is a correctness failure
        let cur_pert = write_obs("cur_pert.json", Some((1.00, false, Some(1.02))));
        let err = gate(&cur_pert, &base_old, 0.75).unwrap_err().to_string();
        assert!(err.contains("obs_bit_identical"), "{err}");
        // stale current vs a baseline that has the section
        let base_new = write_obs("base_new.json", Some((1.00, true, Some(1.02))));
        let cur_stale = write_obs("cur_stale.json", None);
        let err = gate(&cur_stale, &base_new, 0.75).unwrap_err().to_string();
        assert!(err.contains("stale bench artifact"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn refresh_round_trips_current_into_baseline() {
        let dir = std::env::temp_dir().join("dybw_refresh_test");
        std::fs::create_dir_all(&dir).unwrap();
        // current regressed vs the old floor — exactly the re-baseline case
        let current = write_full(&dir, "current.json", 1.2, 1.5, 1.0, true, true);
        let baseline = write_full(&dir, "baseline.json", 3.0, 3.0, 1.0, true, true);
        assert!(gate(&current, &baseline, 0.75).is_err());
        let report = refresh(&current, &baseline, 0.75).unwrap();
        assert!(report.contains("baseline refreshed"), "{report}");
        // byte-for-byte round trip, and the gate now passes
        assert_eq!(std::fs::read(&current).unwrap(), std::fs::read(&baseline).unwrap());
        assert!(gate(&current, &baseline, 0.75).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn refresh_rejects_non_bit_identical_current() {
        let dir = std::env::temp_dir().join("dybw_refresh_reject_test");
        std::fs::create_dir_all(&dir).unwrap();
        let baseline = write_full(&dir, "baseline.json", 2.0, 2.0, 1.0, true, true);
        let before = std::fs::read(&baseline).unwrap();
        let cases = [("cur_a.json", false, true), ("cur_b.json", true, false)];
        for (name, bit, data_bit) in cases {
            let current = write_full(&dir, name, 5.0, 5.0, 5.0, bit, data_bit);
            let err = refresh(&current, &baseline, 0.75).unwrap_err();
            assert!(err.to_string().contains("refusing to install"), "{err}");
            // the baseline file was not touched
            assert_eq!(std::fs::read(&baseline).unwrap(), before);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
