//! Ablations beyond the paper's headline figures.
//!
//! - [`baselines`]: cb-DyBW against the manually-tuned static-backup rule
//!   and the PS family — the comparisons the paper's introduction argues
//!   about qualitatively.
//! - [`topology`]: how the consensus graph affects both convergence and
//!   the achievable θ(k) (the β^{NB} factor in Theorem 1).
//! - [`severity`]: straggler-severity sweep; locates where cb-DyBW's
//!   advantage over cb-Full grows/shrinks (the "which effect prevails?"
//!   question of §1).
//!
//! Every harness fans its independent cells over
//! [`run_cells`](super::run_cells)' bounded scoped-thread scheduler
//! (same pattern as the figure grids): results come back in submission
//! order and each cell is bit-deterministic given its seed, so
//! concurrent output is byte-identical to sequential.

use std::path::Path;

use crate::coordinator::setup::Setup;
use crate::coordinator::Algorithm;
use crate::graph::topology::Topology;
use crate::metrics::export;
use crate::straggler::Dist;

fn one(
    base: &Setup,
    algo: Algorithm,
    iters: usize,
) -> anyhow::Result<crate::metrics::RunHistory> {
    let mut s = base.clone();
    s.algo = algo;
    s.model = "lrm_d64_c10_b256".into();
    s.train.iters = iters;
    s.train.eval_every = (iters / 20).max(1);
    let mut tr = s.build_sim()?;
    tr.run()
}

/// Compressed gossip (extension; paper ref [32]): cb-DyBW with top-k /
/// b-bit quantised parameter exchange + error feedback, vs exact.
pub fn compression(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    use crate::consensus::compress::{Compressor, QuantizeBits, TopK};
    use crate::coordinator::sim::CompressionState;

    let iters = if quick { 40 } else { 250 };
    let mut s = base.clone();
    s.algo = Algorithm::CbDybw;
    s.model = "lrm_d64_c10_b256".into();
    s.train.iters = iters;
    s.train.eval_every = (iters / 20).max(1);
    let meta = s.resolve_meta()?;
    let dim = meta.param_count;
    let n = s.workers;

    let mut out = String::from("=== Compression ablation (cb-DyBW + compressed gossip) ===\n");
    out.push_str(&format!(
        "{:>12} | {:>10} {:>12} {:>14} {:>12}\n",
        "scheme", "final err%", "final loss", "wire MB total", "vs exact"
    ));
    // One cell per scheme (exact first); schemes carry their compressor
    // into the cell, results assemble in submission order.
    let schemes: Vec<(String, Option<Box<dyn Compressor + Send + Sync>>)> = vec![
        ("exact-f32".into(), None),
        ("top-10%".into(), Some(Box::new(TopK { k: dim / 10 }))),
        ("top-25%".into(), Some(Box::new(TopK { k: dim / 4 }))),
        ("8-bit".into(), Some(Box::new(QuantizeBits { bits: 8 }))),
        ("4-bit".into(), Some(Box::new(QuantizeBits { bits: 4 }))),
    ];
    let names: Vec<String> = schemes.iter().map(|(n, _)| n.clone()).collect();
    let jobs: Vec<_> = schemes
        .into_iter()
        .map(|(_, comp)| {
            let s = super::cell_setup(&s);
            move || -> anyhow::Result<(crate::metrics::RunHistory, Option<usize>)> {
                let mut tr = s.build_sim()?;
                let compressed = comp.is_some();
                if let Some(comp) = comp {
                    tr.compression = Some(CompressionState::new(comp, n, dim));
                }
                let h = tr.run()?;
                let wire = compressed.then(|| tr.compression.as_ref().unwrap().wire_bytes);
                Ok((h, wire))
            }
        })
        .collect();
    let results = super::run_cells(jobs)?;
    let exact_bytes_per_round = 2 * (n - 1) * dim * 4; // upper bound: dense both ways
    let exact_loss = results[0].0.final_eval().unwrap().test_loss;
    for (name, (h, wire)) in names.iter().zip(&results) {
        let prefix = if name == "exact-f32" {
            "compression.exact".to_string()
        } else {
            format!("compression.{name}")
        };
        export::write_csv(h, out_dir, &prefix)?;
        let e2 = h.final_eval().unwrap();
        let (mb, vs) = match wire {
            Some(w) => (
                *w as f64 / 1e6,
                format!("{:>11.3}x", e2.test_loss / exact_loss),
            ),
            None => (
                (iters * exact_bytes_per_round) as f64 / 1e6,
                format!("{:>12}", "-"),
            ),
        };
        out.push_str(&format!(
            "{:>12} | {:>10.1} {:>12.4} {:>14.1} {vs}\n",
            name,
            e2.test_error * 100.0,
            e2.test_loss,
            mb
        ));
    }
    out.push_str(
        "(quantisation + error feedback matches exact loss at ~6-13x less\n traffic; naive top-k of *absolute* parameters is too lossy for gossip —\n the CHOCO-style delta-compression fix is future work, see DESIGN.md)\n",
    );
    Ok(out)
}

/// Algorithm shoot-out at fixed workload.
pub fn baselines(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    let iters = if quick { 40 } else { 300 };
    let algos = [
        Algorithm::CbDybw,
        Algorithm::CbFull,
        Algorithm::CbStaticBackup { b: 1 },
        Algorithm::CbStaticBackup { b: 2 },
        Algorithm::CbStaticBackup { b: 3 },
        Algorithm::PsSync,
        Algorithm::PsBackup { b: 2 },
    ];
    let target = 0.55;
    let mut out =
        String::from("=== Baselines: algorithms at fixed workload (LRM, 6 workers) ===\n");
    out.push_str(&format!(
        "{:>16} | {:>10} {:>12} {:>12} {:>14} {:>12}\n",
        "algorithm", "final err%", "final loss", "mean T(k)", "time to loss", "total time"
    ));
    let jobs: Vec<_> = algos
        .iter()
        .map(|&algo| {
            let s = super::cell_setup(base);
            move || one(&s, algo, iters)
        })
        .collect();
    let hists = super::run_cells(jobs)?;
    for h in hists {
        export::write_csv(
            &h,
            out_dir,
            &format!("baselines.{}", h.algo.to_lowercase().replace(['(', ')', '='], "_")),
        )?;
        let e = h.final_eval().unwrap();
        out.push_str(&format!(
            "{:>16} | {:>10.1} {:>12.4} {:>11.3}s {:>14} {:>11.1}s\n",
            h.algo,
            e.test_error * 100.0,
            e.test_loss,
            h.mean_iter_duration(),
            h.time_to_test_loss(target)
                .map(|t| format!("{t:.1}s"))
                .unwrap_or_else(|| "n/a".into()),
            h.total_time()
        ));
    }
    out.push_str("(cb-DyBW should dominate cb-Full on time and match static-b without tuning)\n");
    Ok(out)
}

/// Topology sensitivity.
pub fn topology(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    let iters = if quick { 40 } else { 250 };
    let mut out = String::from("=== Topology ablation (cb-DyBW, LRM) ===\n");
    out.push_str(&format!(
        "{:>10} | {:>10} {:>12} {:>12} {:>14}\n",
        "topology", "final err%", "final loss", "mean T(k)", "consensus err"
    ));
    let topos = [
        Topology::Ring,
        Topology::Grid,
        Topology::RandomConnected,
        Topology::Complete,
    ];
    let jobs: Vec<_> = topos
        .iter()
        .map(|&topo| {
            let mut s = super::cell_setup(base);
            s.topology = topo;
            move || one(&s, Algorithm::CbDybw, iters)
        })
        .collect();
    let hists = super::run_cells(jobs)?;
    for (&topo, h) in topos.iter().zip(&hists) {
        export::write_csv(h, out_dir, &format!("topology.{}", topo.name()))?;
        let e = h.final_eval().unwrap();
        out.push_str(&format!(
            "{:>10} | {:>10.1} {:>12.4} {:>11.3}s {:>14.5}\n",
            topo.name(),
            e.test_error * 100.0,
            e.test_loss,
            h.mean_iter_duration(),
            e.consensus_error
        ));
    }
    out.push_str(
        "(denser graphs mix faster — smaller consensus error — but wait on more links)\n",
    );
    Ok(out)
}

/// Straggler-severity sweep: where does dynamic backup help most?
pub fn severity(base: &Setup, out_dir: &Path, quick: bool) -> anyhow::Result<String> {
    let iters = if quick { 40 } else { 250 };
    let factors: &[f64] = if quick { &[1.0, 6.0] } else { &[1.0, 2.0, 4.0, 8.0, 16.0] };
    let mut out = String::from("=== Straggler severity sweep: cb-DyBW vs cb-Full total time ===\n");
    out.push_str(&format!(
        "{:>8} | {:>12} {:>12} {:>12}\n",
        "slowdown", "dybw total", "full total", "speedup x"
    ));
    let jobs: Vec<_> = factors
        .iter()
        .flat_map(|&f| [(f, Algorithm::CbDybw), (f, Algorithm::CbFull)])
        .map(|(f, algo)| {
            let mut s = super::cell_setup(base);
            s.straggler_factor = f;
            s.force_straggler = f > 1.0;
            s.straggler_base = Dist::ShiftedExp { base: 0.08, rate: 25.0 };
            move || one(&s, algo, iters)
        })
        .collect();
    let mut hists = super::run_cells(jobs)?;
    for &f in factors {
        let ha = hists.remove(0);
        let hb = hists.remove(0);
        export::write_csv(&ha, out_dir, &format!("severity.f{f}.dybw"))?;
        export::write_csv(&hb, out_dir, &format!("severity.f{f}.full"))?;
        out.push_str(&format!(
            "{:>7}x | {:>11.1}s {:>11.1}s {:>12.2}\n",
            f,
            ha.total_time(),
            hb.total_time(),
            hb.total_time() / ha.total_time().max(1e-9)
        ));
    }
    out.push_str("(the speedup factor should grow with straggler severity)\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_setup() -> Setup {
        let mut s = Setup::default();
        s.train_n = 2400;
        s.test_n = 1024;
        s
    }

    #[test]
    fn baselines_quick() {
        let dir = std::env::temp_dir().join("dybw_base_test");
        let out = baselines(&quick_setup(), &dir, true).unwrap();
        assert!(out.contains("cb-DyBW"));
        assert!(out.contains("PS-Sync"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn severity_quick_shows_speedup_column() {
        let dir = std::env::temp_dir().join("dybw_sev_test");
        let out = severity(&quick_setup(), &dir, true).unwrap();
        assert!(out.contains("speedup"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
