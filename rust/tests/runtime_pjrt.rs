//! Integration tests: PJRT artifact path vs the native oracle.
//!
//! These are the cross-layer correctness signal: the HLO produced by
//! JAX+Pallas (Layers 1-2), compiled and executed through the Rust PJRT
//! runtime (Layer 3), must agree numerically with the hand-written native
//! engine on identical inputs.
//!
//! Requires `make artifacts`; every test skips (with a notice) otherwise.
//! The whole file is compile-gated on the `pjrt` cargo feature — the
//! default (offline, dependency-free) build does not touch PJRT.

#![cfg(feature = "pjrt")]

use std::path::Path;
use std::rc::Rc;

use dybw::data::batch::BatchSampler;
use dybw::data::synthetic::{gaussian_mixture, markov_sequences, MixtureSpec};
use dybw::engine::{AnyBatch, GradEngine, NativeEngine};
use dybw::model::ModelMeta;
use dybw::runtime::{shared_client, ArtifactSet, LoadedModel, PjrtEngine};
use dybw::util::rng::Rng;

fn artifacts() -> Option<ArtifactSet> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match ArtifactSet::load(&dir) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

fn load(set: &ArtifactSet, name: &str) -> LoadedModel {
    let art = set.get(name).unwrap_or_else(|| panic!("no artifact {name}"));
    LoadedModel::compile(art, shared_client().unwrap()).unwrap()
}

fn dense_batch(meta: &ModelMeta, seed: u64) -> AnyBatch {
    let mut data = gaussian_mixture(
        &MixtureSpec::mnist_like(meta.dim, meta.batch * 4),
        &mut Rng::new(seed),
    );
    data.classes = meta.classes;
    for y in data.y.iter_mut() {
        *y %= meta.classes as u32;
    }
    AnyBatch::Dense(BatchSampler::new(seed + 1).sample(&data, meta.batch))
}

#[test]
fn lrm_pjrt_matches_native() {
    let Some(set) = artifacts() else { return };
    let model = load(&set, "lrm_d8_c4_b16");
    let meta = model.meta.clone();
    let batch = dense_batch(&meta, 0);
    let w = meta.init_params(&mut Rng::new(7));

    let mut native = NativeEngine::new(meta.clone()).unwrap();
    let mut g_native = vec![0.0f32; meta.param_count];
    let loss_native = native.grad_into(&w, &batch, &mut g_native).unwrap();

    let mut g_pjrt = vec![0.0f32; meta.param_count];
    let loss_pjrt = model.grad_into(&w, &batch, &mut g_pjrt).unwrap();

    assert!(
        (loss_native - loss_pjrt).abs() < 1e-4,
        "loss: native={loss_native} pjrt={loss_pjrt}"
    );
    for (i, (a, b)) in g_native.iter().zip(&g_pjrt).enumerate() {
        assert!(
            (a - b).abs() < 1e-4 + 1e-3 * a.abs(),
            "grad[{i}]: native={a} pjrt={b}"
        );
    }
}

#[test]
fn lrm_pjrt_eval_matches_native() {
    let Some(set) = artifacts() else { return };
    let model = load(&set, "lrm_d8_c4_b16");
    let meta = model.meta.clone();
    let batch = dense_batch(&meta, 3);
    let w = meta.init_params(&mut Rng::new(9));

    let mut native = NativeEngine::new(meta.clone()).unwrap();
    let (l_n, c_n) = native.eval(&w, &batch).unwrap();
    let (l_p, c_p) = model.eval(&w, &batch).unwrap();
    assert!((l_n - l_p).abs() < 1e-4, "loss {l_n} vs {l_p}");
    assert_eq!(c_n, c_p, "correct count");
}

#[test]
fn mlp2_pjrt_matches_native() {
    let Some(set) = artifacts() else { return };
    let model = load(&set, "mlp2_d64_h256_c10_b256");
    let meta = model.meta.clone();
    let batch = dense_batch(&meta, 5);
    let w = meta.init_params(&mut Rng::new(11));

    let mut native = NativeEngine::new(meta.clone()).unwrap();
    let mut g_native = vec![0.0f32; meta.param_count];
    let loss_native = native.grad_into(&w, &batch, &mut g_native).unwrap();

    let mut g_pjrt = vec![0.0f32; meta.param_count];
    let loss_pjrt = model.grad_into(&w, &batch, &mut g_pjrt).unwrap();

    assert!(
        (loss_native - loss_pjrt).abs() < 1e-3,
        "loss: native={loss_native} pjrt={loss_pjrt}"
    );
    let mut max_rel = 0.0f32;
    for (a, b) in g_native.iter().zip(&g_pjrt) {
        let rel = (a - b).abs() / (1e-4 + a.abs().max(b.abs()));
        max_rel = max_rel.max(rel);
    }
    assert!(max_rel < 0.02, "max relative grad deviation {max_rel}");
}

#[test]
fn pjrt_engine_trains_lrm() {
    // SGD through the PJRT engine alone must descend — proves the
    // artifact is a *usable* training step, not just numerically close.
    let Some(set) = artifacts() else { return };
    let model = Rc::new(load(&set, "lrm_d8_c4_b16"));
    let meta = model.meta.clone();
    let mut eng = PjrtEngine::new(model);
    assert_eq!(eng.backend(), "pjrt");

    let mut data = gaussian_mixture(&MixtureSpec::mnist_like(8, 400), &mut Rng::new(13));
    data.classes = 4;
    for y in data.y.iter_mut() {
        *y %= 4;
    }
    let mut sampler = BatchSampler::new(17);
    let mut w = meta.init_params(&mut Rng::new(19));
    let mut g = vec![0.0f32; meta.param_count];
    let probe = AnyBatch::Dense(sampler.sample(&data, 16));
    let l0 = eng.grad_into(&w, &probe, &mut g).unwrap();
    for _ in 0..60 {
        let b = AnyBatch::Dense(sampler.sample(&data, 16));
        eng.grad_into(&w, &b, &mut g).unwrap();
        for (wv, gv) in w.iter_mut().zip(&g) {
            *wv -= 0.4 * gv;
        }
    }
    let l1 = eng.grad_into(&w, &probe, &mut g).unwrap();
    assert!(l1 < l0 * 0.8, "PJRT SGD failed to descend: {l0} -> {l1}");
}

#[test]
fn transformer_artifact_executes_and_descends() {
    let Some(set) = artifacts() else { return };
    let model = load(&set, "tfm_v64_t32_d64_h4_l2_b16");
    let meta = model.meta.clone();
    let seqs = markov_sequences(meta.vocab, meta.seq, 200, &mut Rng::new(23));
    let mut sampler = BatchSampler::new(29);
    let mut w = meta.init_params(&mut Rng::new(31));
    let mut g = vec![0.0f32; meta.param_count];

    let probe = AnyBatch::Seq(sampler.sample_seq(&seqs, meta.batch));
    let l0 = model.grad_into(&w, &probe, &mut g).unwrap();
    assert!(
        (l0 - (meta.vocab as f32).ln()).abs() < 1.0,
        "initial LM loss should be near log(V): {l0}"
    );
    for _ in 0..12 {
        let b = AnyBatch::Seq(sampler.sample_seq(&seqs, meta.batch));
        model.grad_into(&w, &b, &mut g).unwrap();
        for (wv, gv) in w.iter_mut().zip(&g) {
            *wv -= 0.5 * gv;
        }
    }
    let l1 = model.grad_into(&w, &probe, &mut g).unwrap();
    assert!(l1 < l0, "transformer loss did not descend: {l0} -> {l1}");
}

#[test]
fn shape_mismatch_rejected() {
    let Some(set) = artifacts() else { return };
    let model = load(&set, "lrm_d8_c4_b16");
    // wrong batch size
    let wrong = dense_batch(&ModelMeta::lrm(8, 4, 32), 1);
    let w = vec![0.0f32; model.meta.param_count];
    let mut g = vec![0.0f32; model.meta.param_count];
    assert!(model.grad_into(&w, &wrong, &mut g).is_err());
    // wrong param length
    let batch = dense_batch(&model.meta, 2);
    let w_bad = vec![0.0f32; 7];
    assert!(model.grad_into(&w_bad, &batch, &mut g).is_err());
}
