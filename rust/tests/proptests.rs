//! Property-based tests over the coordinator's invariants.
//!
//! No proptest crate offline, so properties are checked over seeded
//! random-case sweeps (200+ cases each) with the failing seed printed —
//! the shrinking story is "rerun with the printed seed".
//!
//! Invariants covered:
//! 1. Metropolis P(k) is doubly stochastic for EVERY graph × participation
//!    pattern (Assumption 1).
//! 2. Mixing preserves the network average exactly (the conservation the
//!    convergence proof rides on).
//! 3. DTUR epochs always establish all of P within d iterations
//!    (Assumption 2 with B = d).
//! 4. DTUR's θ(k) ≤ max_j t_j(k) — Corollary 4's pathwise dominance.
//! 5. Partitioners cover every example exactly once.
//! 6. The connecting path P spans all nodes with exactly N-1 in-graph
//!    edges, for every connected graph.
//! 7. Repeated partial-participation mixing still contracts disagreement
//!    when every epoch's union graph is connected.

use dybw::consensus::mixing::ParamBuffers;
use dybw::consensus::ConsensusMatrix;
use dybw::coordinator::dtur::Dtur;
use dybw::data::partition::{split, Partition};
use dybw::data::synthetic::{gaussian_mixture, MixtureSpec};
use dybw::graph::{paths, topology};
use dybw::straggler::{Dist, StragglerModel};
use dybw::util::rng::Rng;

fn random_graph(rng: &mut Rng) -> dybw::graph::Graph {
    let n = 2 + rng.below(14);
    let p = rng.uniform_in(0.15, 0.8);
    topology::random_connected(n, p, rng)
}

#[test]
fn prop_metropolis_doubly_stochastic() {
    for seed in 0..300u64 {
        let mut rng = Rng::new(seed);
        let g = random_graph(&mut rng);
        let active: Vec<bool> = (0..g.n()).map(|_| rng.uniform() < rng.uniform()).collect();
        let p = ConsensusMatrix::metropolis(&g, &active);
        p.check_doubly_stochastic(1e-10)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        // beta in (0, 1]
        let beta = p.min_positive();
        assert!(beta > 0.0 && beta <= 1.0, "seed {seed}: beta={beta}");
    }
}

#[test]
fn prop_mixing_preserves_average() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(1000 + seed);
        let g = random_graph(&mut rng);
        let n = g.n();
        let dim = 1 + rng.below(300);
        let init: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut bufs = ParamBuffers::from_initial(init);
        let avg0 = bufs.average();
        for _ in 0..15 {
            let active: Vec<bool> = (0..n).map(|_| rng.uniform() < 0.6).collect();
            bufs.mix(&ConsensusMatrix::metropolis(&g, &active));
        }
        let avg1 = bufs.average();
        for (a, b) in avg0.iter().zip(&avg1) {
            assert!(
                (a - b).abs() < 1e-3,
                "seed {seed}: average drifted {a} -> {b}"
            );
        }
    }
}

#[test]
fn prop_dtur_epoch_covers_path() {
    for seed in 0..150u64 {
        let mut rng = Rng::new(2000 + seed);
        let g = random_graph(&mut rng);
        let mut dtur = Dtur::new(&g);
        let d = dtur.d();
        let model = StragglerModel::homogeneous(
            g.n(),
            Dist::ShiftedExp {
                base: rng.uniform_in(0.01, 0.1),
                rate: rng.uniform_in(5.0, 40.0),
            },
        );
        // run 3 epochs; within each, every link must establish
        for _epoch in 0..3 {
            let mut covered = vec![false; d];
            for _ in 0..d {
                let t = model.sample_iteration(&mut rng);
                let dec = dtur.step(&t);
                for idx in &dec.established_now {
                    covered[*idx] = true;
                }
                if dec.epoch_pos == 0 {
                    break;
                }
            }
            assert!(
                covered.iter().all(|&c| c),
                "seed {seed}: epoch ended with uncovered links {covered:?}"
            );
        }
    }
}

#[test]
fn prop_dtur_theta_dominated_by_max() {
    for seed in 0..150u64 {
        let mut rng = Rng::new(3000 + seed);
        let g = random_graph(&mut rng);
        let mut dtur = Dtur::new(&g);
        for _ in 0..20 {
            let t: Vec<f64> = (0..g.n()).map(|_| rng.uniform_in(0.01, 2.0)).collect();
            let tmax = t.iter().copied().fold(0.0, f64::max);
            let dec = dtur.step(&t);
            assert!(
                dec.theta <= tmax + 1e-12,
                "seed {seed}: theta {} > max {}",
                dec.theta,
                tmax
            );
            // the triggering link's endpoints are active
            assert!(dec.active.iter().any(|&a| a), "seed {seed}: nobody active");
        }
    }
}

#[test]
fn prop_partition_exact_cover() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(4000 + seed);
        let n = 200 + rng.below(2000);
        let workers = 2 + rng.below(9);
        let data = gaussian_mixture(&MixtureSpec::mnist_like(6, n), &mut rng);
        for how in [
            Partition::Iid,
            Partition::LabelShards,
            Partition::Dirichlet { alpha: 0.5 },
        ] {
            let parts = split(&data, workers, how, &mut rng);
            let total: usize = parts.iter().map(|p| p.n()).sum();
            assert_eq!(total, n, "seed {seed} {how:?}: lost/duplicated rows");
            // label-count checksum: each example exactly once
            let mut want = data.class_counts();
            for p in &parts {
                for (w, c) in want.iter_mut().zip(p.class_counts()) {
                    *w = w.wrapping_sub(c);
                }
            }
            assert!(
                want.iter().all(|&w| w == 0),
                "seed {seed} {how:?}: class counts unbalanced"
            );
        }
    }
}

#[test]
fn prop_connecting_path_valid() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(5000 + seed);
        let g = random_graph(&mut rng);
        let p = paths::connecting_path(&g);
        assert_eq!(p.len(), g.n() - 1, "seed {seed}");
        assert!(paths::spans_all(g.n(), &p), "seed {seed}");
        for &(a, b) in &p {
            assert!(g.has_edge(a, b), "seed {seed}: ({a},{b}) not an edge");
        }
    }
}

#[test]
fn prop_partial_participation_contracts_disagreement() {
    // Over enough DTUR-driven epochs the union connectivity must shrink
    // max_j ||w_j - avg|| (Corollary 1 pathway).
    for seed in 0..25u64 {
        let mut rng = Rng::new(6000 + seed);
        let g = random_graph(&mut rng);
        let n = g.n();
        let mut dtur = Dtur::new(&g);
        let model = StragglerModel::homogeneous(n, Dist::Uniform { lo: 0.05, hi: 0.5 });
        let init: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..32).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut bufs = ParamBuffers::from_initial(init);
        let e0 = bufs.consensus_error();
        let rounds = 20 * dtur.d().max(1);
        for _ in 0..rounds {
            let t = model.sample_iteration(&mut rng);
            let dec = dtur.step(&t);
            bufs.mix(&ConsensusMatrix::metropolis(&g, &dec.active));
        }
        let e1 = bufs.consensus_error();
        assert!(
            e1 < e0 * 0.5,
            "seed {seed}: disagreement {e0} -> {e1} after {rounds} rounds (n={n})"
        );
    }
}

#[test]
fn prop_straggler_samples_positive_finite() {
    for seed in 0..50u64 {
        let mut rng = Rng::new(7000 + seed);
        let n = 2 + rng.below(12);
        let mut model = StragglerModel::paper_default(n, &mut rng);
        model.transient_factor = rng.uniform_in(1.0, 20.0);
        for _ in 0..50 {
            for t in model.sample_iteration(&mut rng) {
                assert!(t.is_finite() && t > 0.0);
            }
        }
    }
}
