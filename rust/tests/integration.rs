//! Cross-module integration tests: full training runs through the public
//! API, theory-facing behaviours, and failure injection.

// Same rationale as the crate-level allows in lib.rs.
#![allow(clippy::field_reassign_with_default)]

use dybw::coordinator::setup::{DatasetProfile, Setup};
use dybw::coordinator::{Algorithm, TrainConfig};
use dybw::data::partition::Partition;
use dybw::metrics::summary::Comparison;
use dybw::straggler::Dist;

fn quick_setup(seed: u64) -> Setup {
    let mut s = Setup::default();
    s.model = "lrm_d16_c10_b64".into();
    s.train_n = 3_000;
    s.test_n = 640;
    s.train = TrainConfig {
        iters: 80,
        batch_size: 64,
        eval_every: 8,
        seed,
        ..Default::default()
    };
    s
}

#[test]
fn headline_claim_duration_reduction_55_to_75_pct() {
    // Paper Fig. 1(c)/4(c): cb-DyBW cuts mean iteration duration by
    // 55-70% under at-least-one-straggler-per-iteration. Assert our
    // harness lands in a band around that.
    let mut a = quick_setup(42);
    a.algo = Algorithm::CbDybw;
    let mut b = quick_setup(42);
    b.algo = Algorithm::CbFull;
    let ha = a.build_sim().unwrap().run().unwrap();
    let hb = b.build_sim().unwrap().run().unwrap();
    let reduction = 1.0 - ha.mean_iter_duration() / hb.mean_iter_duration();
    assert!(
        (0.4..0.85).contains(&reduction),
        "duration reduction {reduction} outside plausible band"
    );
}

#[test]
fn headline_claim_similar_iterations_to_converge() {
    // Paper: "the number of iterations required for convergence is
    // similar (in order sense) for both cb-DyBW and cb-Full".
    let mut a = quick_setup(7);
    a.algo = Algorithm::CbDybw;
    let mut b = quick_setup(7);
    b.algo = Algorithm::CbFull;
    let ha = a.build_sim().unwrap().run().unwrap();
    let hb = b.build_sim().unwrap().run().unwrap();
    let target = 1.0;
    let (ka, kb) = (
        ha.iters_to_test_loss(target),
        hb.iters_to_test_loss(target),
    );
    let (ka, kb) = (ka.expect("dybw reached target"), kb.expect("full reached target"));
    let ratio = ka as f64 / kb as f64;
    assert!(
        (0.5..2.0).contains(&ratio),
        "iteration counts not of similar order: {ka} vs {kb}"
    );
    // and the wall-clock comparison favours DyBW
    let c = Comparison::new(&ha, &hb, target);
    assert!(c.convergence_time_reduction.unwrap() > 0.3, "{c:?}");
}

#[test]
fn non_iid_partitions_still_converge() {
    for part in [Partition::LabelShards, Partition::Dirichlet { alpha: 0.3 }] {
        let mut s = quick_setup(11);
        s.partition = part;
        s.train.iters = 120;
        let h = s.build_sim().unwrap().run().unwrap();
        let first = h.evals.first().unwrap().test_loss;
        let last = h.evals.last().unwrap().test_loss;
        assert!(
            last < first * 0.75,
            "{part:?}: loss {first} -> {last} (no progress)"
        );
    }
}

#[test]
fn cifar_profile_is_harder_than_mnist() {
    // Paper Fig. 1: LRM error floor differs sharply between datasets.
    let mut easy = quick_setup(13);
    easy.dataset = DatasetProfile::MnistLike;
    let mut hard = quick_setup(13);
    hard.dataset = DatasetProfile::CifarLike;
    let he = easy.build_sim().unwrap().run().unwrap();
    let hh = hard.build_sim().unwrap().run().unwrap();
    let (ee, eh) = (
        he.final_eval().unwrap().test_error,
        hh.final_eval().unwrap().test_error,
    );
    assert!(eh > ee + 0.1, "cifar-like err {eh} not >> mnist-like {ee}");
}

#[test]
fn persistent_straggler_does_not_stall_dybw() {
    // Failure injection: one worker persistently 20x slower (~2.4s vs
    // ~0.12s healthy). cb-Full pays the full 2.4s EVERY iteration;
    // cb-DyBW pays it only on the epoch iterations whose remaining
    // P-links touch the straggler (Assumption 2 forces those through),
    // i.e. roughly (straggler's P-degree)/d of iterations. Assert the
    // amortised duration is well below the baseline's.
    let mut s = quick_setup(17);
    s.algo = Algorithm::CbDybw;
    let mut trainer = s.build_sim().unwrap();
    trainer.straggler.persistent[2] = 20.0;
    let h = trainer.run().unwrap();
    assert!(
        h.mean_iter_duration() < 1.5,
        "cb-DyBW stalled on persistent straggler: {}s",
        h.mean_iter_duration()
    );
    // and still learns
    assert!(h.final_eval().unwrap().test_loss < h.evals[0].test_loss);

    let mut sf = quick_setup(17);
    sf.algo = Algorithm::CbFull;
    let mut tf = sf.build_sim().unwrap();
    tf.straggler.persistent[2] = 20.0;
    let hf = tf.run().unwrap();
    assert!(
        h.mean_iter_duration() < 0.65 * hf.mean_iter_duration(),
        "dybw {}s not clearly better than full {}s",
        h.mean_iter_duration(),
        hf.mean_iter_duration()
    );
}

#[test]
fn persistent_straggler_stalls_full_baseline() {
    // The same fault makes cb-Full's iteration time balloon (the paper's
    // motivation for backup workers in the first place).
    let mut s = quick_setup(17);
    s.algo = Algorithm::CbFull;
    let mut trainer = s.build_sim().unwrap();
    trainer.straggler.persistent[2] = 20.0;
    let h = trainer.run().unwrap();
    assert!(
        h.mean_iter_duration() > 1.5,
        "expected cb-Full to stall: {}s",
        h.mean_iter_duration()
    );
}

#[test]
fn deterministic_straggler_no_injection_equalises_algorithms() {
    // With identical deterministic compute times there are no stragglers;
    // DyBW's advantage must collapse (sanity: no free lunch). Neutralise
    // Setup's per-worker heterogeneity too.
    let run = |algo: Algorithm| {
        let mut s = quick_setup(19);
        s.straggler_base = Dist::Deterministic { base: 0.1 };
        s.straggler_factor = 1.0;
        s.force_straggler = false;
        s.algo = algo;
        let mut t = s.build_sim().unwrap();
        t.straggler.worker_scale = vec![1.0; 6];
        t.straggler.transient_prob = 0.0;
        t.run().unwrap()
    };
    let ha = run(Algorithm::CbDybw);
    let hb = run(Algorithm::CbFull);
    let ratio = ha.mean_iter_duration() / hb.mean_iter_duration();
    assert!(
        (ratio - 1.0).abs() < 1e-9,
        "without stragglers durations should match: ratio {ratio}"
    );
}

#[test]
fn ten_worker_network_fig2_runs() {
    let mut s = quick_setup(23);
    s.workers = 10;
    s.train.iters = 60;
    let h = s.build_sim().unwrap().run().unwrap();
    assert_eq!(h.workers, 10);
    assert!(h.final_eval().unwrap().test_loss < h.evals[0].test_loss);
}

#[test]
fn larger_batch_reduces_gradient_noise() {
    // Figure 3 mechanism: larger batches give smoother convergence. Use
    // final consensus of train loss trajectory variance as proxy.
    let run_with = |bsz: usize, seed: u64| -> f64 {
        let mut s = quick_setup(seed);
        s.model = format!("lrm_d16_c10_b{bsz}");
        s.train.iters = 60;
        let h = s.build_sim().unwrap().run().unwrap();
        // variance of successive train-loss diffs in the tail
        let tail: Vec<f64> = h.iters[30..].iter().map(|r| r.train_loss).collect();
        let diffs: Vec<f64> = tail.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = diffs.iter().sum::<f64>() / diffs.len() as f64;
        diffs.iter().map(|d| (d - mean).powi(2)).sum::<f64>() / diffs.len() as f64
    };
    let noisy = run_with(16, 29);
    let smooth = run_with(256, 29);
    assert!(
        smooth < noisy,
        "batch 256 should be smoother: var {smooth} vs {noisy}"
    );
}

#[test]
fn ps_baselines_converge_with_exact_averaging() {
    for algo in [Algorithm::PsSync, Algorithm::PsBackup { b: 2 }] {
        let mut s = quick_setup(31);
        s.algo = algo;
        let h = s.build_sim().unwrap().run().unwrap();
        let e = h.final_eval().unwrap();
        assert!(e.consensus_error < 1e-4, "{algo:?}: PS must keep exact consensus");
        assert!(e.test_loss < h.evals[0].test_loss, "{algo:?} did not learn");
    }
}

#[test]
fn empty_or_tiny_configs_rejected() {
    // failure injection on the builder
    let mut s = quick_setup(37);
    s.workers = 1;
    assert!(s.build_sim().is_err(), "single worker must be rejected");

    let mut s = quick_setup(37);
    s.test_n = 8; // smaller than one artifact batch (64)
    assert!(s.build_sim().is_err(), "test set < one batch must error");

    let mut s = quick_setup(37);
    s.model = "nonsense".into();
    assert!(s.build_sim().is_err());
}

#[test]
fn same_seed_is_bit_identical_across_all_algorithms() {
    // Seeded-RNG determinism guarantee: a TrainConfig seed fully
    // determines the run. Re-running the identical Setup must reproduce
    // every duration, loss, and eval record BIT-identically — for
    // cb-DyBW, cb-Full, static-backup, and both PS baselines. (All
    // randomness flows through util::rng::Rng; containers are BTree-based;
    // the GEMM thread partition is fixed per process.)
    for algo in [
        Algorithm::CbDybw,
        Algorithm::CbFull,
        Algorithm::CbStaticBackup { b: 2 },
        Algorithm::PsSync,
        Algorithm::PsBackup { b: 1 },
    ] {
        let run = || {
            let mut s = quick_setup(101);
            s.algo = algo;
            s.train.iters = 30;
            s.build_sim().unwrap().run().unwrap()
        };
        let h1 = run();
        let h2 = run();
        assert_eq!(h1.iters.len(), h2.iters.len(), "{algo:?}");
        for (a, b) in h1.iters.iter().zip(&h2.iters) {
            assert_eq!(
                a.duration.to_bits(),
                b.duration.to_bits(),
                "{algo:?} k={}: duration drifted",
                a.k
            );
            assert_eq!(a.clock.to_bits(), b.clock.to_bits(), "{algo:?} k={}", a.k);
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "{algo:?} k={}: loss drifted",
                a.k
            );
            assert_eq!(a.active, b.active, "{algo:?} k={}", a.k);
            assert_eq!(a.theta.to_bits(), b.theta.to_bits(), "{algo:?} k={}", a.k);
        }
        assert_eq!(h1.evals.len(), h2.evals.len(), "{algo:?}");
        for (a, b) in h1.evals.iter().zip(&h2.evals) {
            assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits(), "{algo:?}");
            assert_eq!(a.test_error.to_bits(), b.test_error.to_bits(), "{algo:?}");
            assert_eq!(
                a.consensus_error.to_bits(),
                b.consensus_error.to_bits(),
                "{algo:?}"
            );
        }
    }
}

#[test]
fn des_build_from_setup_is_policy_fair() {
    // Setup::build_des records the compute-time trace as a pure
    // function of the seed, so two policies built at the same seed
    // replay the IDENTICAL timing realisation: the asynchronous
    // dynamic-backup run must beat the asynchronous full barrier on
    // makespan while training on the same data to a finite loss.
    use dybw::des::WaitPolicy;
    use dybw::graph::topology::Topology;
    use dybw::straggler::link::LinkModel;
    let mut s = quick_setup(21);
    // a ring, long enough to average out per-seed luck: on dense random
    // graphs at few iterations the makespan can be dominated by one
    // unlucky worker's own compute, where no policy can win
    s.topology = Topology::Ring;
    s.train.iters = 30;
    let run = |policy| {
        let mut t = s.build_des(policy, LinkModel::zero()).unwrap();
        t.run().unwrap()
    };
    let dybw = run(WaitPolicy::Dybw);
    let full = run(WaitPolicy::Full);
    assert!(
        dybw.stats.makespan < 0.97 * full.stats.makespan,
        "async dybw {}s vs full {}s on the identical trace",
        dybw.stats.makespan,
        full.stats.makespan
    );
    // every worker mixed every iteration exactly once
    assert_eq!(dybw.history.iters.len(), s.workers * 30);
    assert!(dybw.history.final_eval().unwrap().test_loss.is_finite());
    // the wait rule kept per-epoch neighbour coverage intact
    assert_eq!(dybw.stats.coverage_violations, 0);
}

#[test]
fn des_scenario_artifacts_identical_with_obs_installed() {
    // Telemetry byte-identity sentinel: an installed observer may read
    // clocks but never the RNG or the parameters, so every artifact a
    // DES scenario exports (per-policy summary JSON + streamed event
    // log) must be byte-identical to the same-seed run without one —
    // with and without injected churn/partition faults (the `--chaos`
    // shape). This test is the only obs::install caller in this binary,
    // so the process-wide observer needs no cross-test serialisation.
    use dybw::des::{Scenario, ScenarioFaults};

    let artifacts = |sc: &Scenario, tag: &str, observe: bool| -> (Vec<u8>, Vec<u8>) {
        let base = std::env::temp_dir().join(format!(
            "dybw_obs_ident_{tag}_{}_{}",
            if observe { "on" } else { "off" },
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&base);
        let events = base.join("events.log");
        let obs = observe.then(|| {
            let o = dybw::obs::Obs::to_dir(&base.join("obs")).unwrap();
            dybw::obs::install(o.clone());
            o
        });
        let run = sc.run(&base, Some(&events));
        if let Some(o) = &obs {
            dybw::obs::uninstall();
            o.finish().unwrap();
        }
        run.unwrap();
        if observe {
            // the observer really recorded: DES mix spans on per-policy
            // worker tracks, and the straggler report reads them back
            let jsonl =
                std::fs::read_to_string(base.join("obs").join("trace.jsonl")).unwrap();
            assert!(
                jsonl.lines().any(|l| l.contains("dybw/worker-")),
                "{tag}: no dybw worker tracks in the trace"
            );
            let report = dybw::obs::report::report(&base.join("obs"), 3).unwrap();
            assert!(report.contains("worker"), "{tag}: empty report:\n{report}");
        }
        let summary =
            std::fs::read(base.join(format!("des.{}.summary.json", sc.name))).unwrap();
        let log = std::fs::read(&events).unwrap();
        let _ = std::fs::remove_dir_all(&base);
        (summary, log)
    };

    let mut clean = Scenario::default();
    clean.name = "obs-ident".into();
    clean.workers = 64;
    clean.iters = 10;
    let mut chaos = clean.clone();
    chaos.name = "obs-ident-chaos".into();
    chaos.faults = ScenarioFaults {
        initially_down: vec![5],
        joins: vec![(5, 1.0), (3, 2.5)],
        leaves: vec![(3, 0.8)],
        partitions: vec![(0, 1, 0.2, 1.5)],
        rack_outages: Vec::new(),
    };
    for (sc, tag) in [(&clean, "clean"), (&chaos, "chaos")] {
        let (sum_off, log_off) = artifacts(sc, tag, false);
        let (sum_on, log_on) = artifacts(sc, tag, true);
        assert_eq!(sum_off, sum_on, "{tag}: observer changed the summary JSON");
        assert_eq!(log_off, log_on, "{tag}: observer changed the event log");
        assert!(!log_off.is_empty(), "{tag}: empty event log");
    }
}

#[test]
fn lr_schedule_matches_paper_form() {
    let cfg = TrainConfig {
        lr0: 0.2,
        lr_decay: 0.95,
        lr_decay_every: 10,
        ..Default::default()
    };
    assert!((cfg.lr(0) - 0.2).abs() < 1e-12);
    assert!((cfg.lr(10) - 0.2 * 0.95).abs() < 1e-12);
    assert!((cfg.lr(100) - 0.2 * 0.95f64.powi(10)).abs() < 1e-12);
}
