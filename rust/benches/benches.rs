//! `cargo bench` — hot-path and end-to-end benchmarks.
//!
//! No criterion in the offline vendor set, so this carries a small
//! criterion-style harness: warmup, N timed samples, mean/median/p95,
//! and a throughput column where meaningful. Benchmarks:
//!
//! hot paths (the Layer-3 per-iteration costs):
//!   mix/*          — eq. (6) Metropolis averaging over flat params
//!                    (sequential loop, and pooled row fan-out vs lanes)
//!   vecmath/*      — dot/axpy kernels (4-lane chunked accumulation)
//!   metropolis/*   — consensus-matrix construction
//!   dtur/step      — Algorithm 2 threshold decision
//!   grad/native-*  — native engine gradient (LRM / 2NN)
//!   grad/pjrt-*    — PJRT artifact gradient (when artifacts built)
//!   pool/*         — 16-worker gradient fan-out vs engine-pool size
//!   synth/*        — gaussian-mixture synthesis vs pool size (the
//!                    bit-identical counter-based substream fan-out)
//!   des/*          — event-driven simulator throughput (10k/100k/1M-worker
//!                    rings, timing-only, events/second)
//!   obs/*          — telemetry overhead: the 10k-worker DES with a
//!                    registry-only observer attached vs none (CI gates
//!                    the ratio at < 2%)
//!
//! end-to-end (figure-scale workloads, small iteration counts):
//!   iter/cb-dybw, iter/cb-full — one full training iteration
//!   sim/mlp-16w-t* — sim-driver wall clock, sequential vs pooled
//!
//! Filter with `cargo bench -- <substring>`.

// Same rationale as the crate-level allows in lib.rs.
#![allow(clippy::field_reassign_with_default)]

use std::time::Instant;

use dybw::consensus::mixing::ParamBuffers;
use dybw::consensus::ConsensusMatrix;
use dybw::coordinator::dtur::Dtur;
use dybw::coordinator::setup::{Backend, Setup};
use dybw::coordinator::Algorithm;
use dybw::data::batch::BatchSampler;
use dybw::data::synthetic::{gaussian_mixture, MixtureSpec};
use dybw::engine::{native_factory, AnyBatch, EnginePool, GradEngine, NativeEngine};
use dybw::graph::topology;
use dybw::model::ModelMeta;
use dybw::straggler::{Dist, StragglerModel};
use dybw::util::rng::Rng;

// ---------------------------------------------------------------------------
// mini-harness
// ---------------------------------------------------------------------------

struct BenchResult {
    name: String,
    mean_ns: f64,
    median_ns: f64,
    p95_ns: f64,
    throughput: Option<String>,
}

fn bench<F: FnMut()>(name: &str, samples: usize, mut f: F) -> BenchResult {
    // warmup
    for _ in 0..3.max(samples / 10) {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as f64);
    }
    times.sort_by(f64::total_cmp);
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        median_ns: times[times.len() / 2],
        p95_ns: times[((times.len() as f64 * 0.95) as usize).min(times.len() - 1)],
        throughput: None,
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.1}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

fn print_result(r: &BenchResult) {
    println!(
        "{:<34} mean {:>10}  median {:>10}  p95 {:>10}{}",
        r.name,
        fmt_ns(r.mean_ns),
        fmt_ns(r.median_ns),
        fmt_ns(r.p95_ns),
        r.throughput
            .as_ref()
            .map(|t| format!("  [{t}]"))
            .unwrap_or_default()
    );
}

fn wants(filter: &Option<String>, name: &str) -> bool {
    filter.as_ref().map_or(true, |f| name.contains(f.as_str()))
}

// ---------------------------------------------------------------------------

fn main() {
    let filter: Option<String> = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-') && a != "--bench");
    println!("# dybw benchmarks (filter: {:?})\n", filter);

    bench_mixing(&filter);
    bench_mix_pooled(&filter);
    bench_vecmath(&filter);
    bench_metropolis(&filter);
    bench_dtur(&filter);
    bench_native_grad(&filter);
    bench_pjrt_grad(&filter);
    bench_pool(&filter);
    bench_synth(&filter);
    bench_des(&filter);
    bench_obs_overhead(&filter);
    bench_end_to_end(&filter);
}

/// The observability price tag: the 10k-worker DES case from
/// `bench_des`, run with a registry-only observer attached vs none.
/// The printed ratio is what `figure speedup` measures and the CI
/// `des-bench` job gates (registry live must cost < 2%).
fn bench_obs_overhead(filter: &Option<String>) {
    use dybw::des::{ClusterSim, ComputeTimes, NoHooks, WaitPolicy};
    use dybw::straggler::link::LinkModel;
    if !wants(filter, "obs/overhead") {
        return;
    }
    let (n, iters, samples) = (10_000usize, 10usize, 5usize);
    let times = ComputeTimes::PerWorker {
        dist: Dist::ShiftedExp { base: 0.08, rate: 25.0 },
        scale: vec![1.0; n],
        seed: 11,
    };
    let link = LinkModel::new(0.002, Some(Dist::ShiftedExp { base: 0.0, rate: 800.0 }), 12);
    let mut means = [0.0f64; 2];
    for (slot, case) in [(0usize, "obs/overhead-des-10k-off"), (1, "obs/overhead-des-10k-on")] {
        let obs = (slot == 1).then(dybw::obs::Obs::registry_only);
        let r = bench(case, samples, || {
            let mut sim = ClusterSim::new(
                topology::ring(n),
                WaitPolicy::Dybw,
                iters,
                times.clone(),
                link.clone(),
            )
            .unwrap();
            sim.set_obs(obs.clone());
            let stats = sim.run(&mut NoHooks).unwrap();
            std::hint::black_box(stats.events);
        });
        means[slot] = r.mean_ns;
        print_result(&r);
    }
    println!(
        "{:<34} {:.4}x registry-on vs off (CI gates <= 1.02)",
        "obs/overhead-ratio",
        means[1] / means[0]
    );
}

/// The event-driven core at scale: dybw-policy rings, timing-only.
/// Measures raw throughput of the calendar event queue + the CSR/bitset
/// per-worker state machines; compute/link times are pure functions of
/// their coordinates, so memory stays flat in the iteration count. The
/// 10k case is the quick smoke number, 100k matches the scale whose
/// events/sec `figure speedup` measures and CI gates, and the 1M case
/// (one sample, few iterations) exercises the regime the calendar
/// queue exists for.
fn bench_des(filter: &Option<String>) {
    use dybw::des::{ClusterSim, ComputeTimes, NoHooks, WaitPolicy};
    use dybw::straggler::link::LinkModel;
    let cases: [(&str, usize, usize, usize); 3] = [
        ("des/events-10k-workers", 10_000, 10, 5),
        ("des/events-100k-workers", 100_000, 5, 3),
        ("des/events-1m-workers", 1_000_000, 3, 1),
    ];
    for (name, n, iters, samples) in cases {
        if !wants(filter, name) {
            continue;
        }
        let times = ComputeTimes::PerWorker {
            dist: Dist::ShiftedExp { base: 0.08, rate: 25.0 },
            scale: vec![1.0; n],
            seed: 11,
        };
        let link = LinkModel::new(0.002, Some(Dist::ShiftedExp { base: 0.0, rate: 800.0 }), 12);
        let mut events = 0u64;
        let mut r = bench(name, samples, || {
            let mut sim = ClusterSim::new(
                topology::ring(n),
                WaitPolicy::Dybw,
                iters,
                times.clone(),
                link.clone(),
            )
            .unwrap();
            let stats = sim.run(&mut NoHooks).unwrap();
            events = stats.events;
            std::hint::black_box(stats.makespan);
        });
        r.throughput = Some(format!("{:.2}M events/s", events as f64 * 1e3 / r.mean_ns));
        print_result(&r);
    }
}

/// The vecmath micro-kernels: `dot` (4 independent f64 accumulation
/// lanes — the reduction that bounds `norm2`/`dist`-style metrics) and
/// `axpy` (the eq. (5) parameter update).
fn bench_vecmath(filter: &Option<String>) {
    use dybw::util::vecmath;
    let n = 1_000_000usize;
    let a: Vec<f32> = (0..n).map(|i| ((i % 1013) as f32) * 0.001 - 0.5).collect();
    let b: Vec<f32> = (0..n).map(|i| ((i % 997) as f32) * 0.001 - 0.4).collect();
    if wants(filter, "vecmath/dot-1m") {
        let mut acc = 0.0f64;
        let mut r = bench("vecmath/dot-1m", 50, || {
            acc += std::hint::black_box(vecmath::dot(&a, &b));
        });
        r.throughput = Some(format!("{:.2} GB/s", (n * 8) as f64 / r.mean_ns));
        print_result(&r);
        std::hint::black_box(acc);
    }
    if wants(filter, "vecmath/axpy-1m") {
        let mut y = vec![0.0f32; n];
        let mut r = bench("vecmath/axpy-1m", 50, || {
            vecmath::axpy(&mut y, 0.5, &a);
        });
        r.throughput = Some(format!("{:.2} GB/s", (n * 12) as f64 / r.mean_ns));
        print_result(&r);
        std::hint::black_box(y[0]);
    }
}

/// Pooled data synthesis: the gaussian-mixture generator fanned over the
/// pool's lanes via counter-based RNG substreams. t1 falls back to the
/// sequential generator, so the ratio is the cold-start win every figure
/// sweep sees; results are bit-identical at any lane count.
fn bench_synth(filter: &Option<String>) {
    use dybw::data::synthetic::gaussian_mixture_pooled;
    let spec = MixtureSpec::mnist_like(64, 60_000);
    let mut t1_mean = None;
    for threads in [1usize, 2, 4] {
        let name = format!("synth/mixture-60k-t{threads}");
        if !wants(filter, &name) {
            continue;
        }
        let pool = EnginePool::tasks_only(threads).unwrap();
        let mut r = bench(&name, 5, || {
            let mut rng = Rng::new(3);
            let d = gaussian_mixture_pooled(&spec, &mut rng, &pool).unwrap();
            std::hint::black_box(d.n());
        });
        if threads == 1 {
            t1_mean = Some(r.mean_ns);
        }
        r.throughput = match t1_mean {
            Some(base) if threads > 1 => Some(format!("{:.2}x vs t1", base / r.mean_ns)),
            _ => None,
        };
        print_result(&r);
    }
}

/// The refactor's headline: one iteration's 16 worker gradients, fanned
/// over pools of increasing size. t1 is the pre-refactor baseline — one
/// gradient at a time, with full intra-op GEMM threading (a T-lane pool
/// caps each lane's kernels at cores/T, so parallelism composes instead
/// of oversubscribing).
fn bench_pool(filter: &Option<String>) {
    let meta = ModelMeta::mlp2(64, 256, 10, 256);
    let workers = 16usize;
    let mut rng = Rng::new(6);
    let mut data = gaussian_mixture(&MixtureSpec::mnist_like(meta.dim, meta.batch * 4), &mut rng);
    data.classes = meta.classes;
    for y in data.y.iter_mut() {
        *y %= meta.classes as u32;
    }
    let mut sampler = BatchSampler::new(7);
    let batches: Vec<AnyBatch> = (0..workers)
        .map(|_| AnyBatch::Dense(sampler.sample(&data, meta.batch)))
        .collect();
    let w = meta.init_params(&mut rng);
    let mut t1_mean = None;
    for threads in [1usize, 2, 4] {
        let name = format!("pool/grad16-mlp-t{threads}");
        if !wants(filter, &name) {
            continue;
        }
        let pool = EnginePool::new(native_factory(meta.clone()), threads).unwrap();
        let ws: Vec<&[f32]> = (0..workers).map(|_| w.as_slice()).collect();
        let mut outs = vec![vec![0.0f32; meta.param_count]; workers];
        let mut r = bench(&name, 10, || {
            std::hint::black_box(pool.grad_many(&ws, &batches, &mut outs).unwrap());
        });
        if threads == 1 {
            t1_mean = Some(r.mean_ns);
        }
        r.throughput = match t1_mean {
            Some(base) if threads > 1 => Some(format!("{:.2}x vs t1", base / r.mean_ns)),
            _ => Some(format!("{:.1} grad/s", workers as f64 * 1e9 / r.mean_ns)),
        };
        print_result(&r);
    }
}

fn bench_mixing(filter: &Option<String>) {
    for (n, p) in [(6usize, 85_002usize), (6, 1_000_000), (16, 85_002)] {
        let name = format!("mix/n{n}_p{}k", p / 1000);
        if !wants(filter, &name) {
            continue;
        }
        let mut rng = Rng::new(0);
        let g = topology::random_connected(n, 0.5, &mut rng);
        let pm = ConsensusMatrix::metropolis_full(&g);
        let init: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..p).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut bufs = ParamBuffers::from_initial(init);
        let mut r = bench(&name, 30, || bufs.mix(&pm));
        // bytes touched per mix ≈ reads of all sources per row + writes
        let edges: usize = (0..n).map(|j| pm.row(j).len()).sum();
        let bytes = (edges * p + n * p) * 4;
        r.throughput = Some(format!(
            "{:.1} GB/s",
            bytes as f64 / r.mean_ns
        ));
        print_result(&r);
    }
}

/// The mixing-parallelism tentpole: the same eq. (6) round fanned over
/// pool lanes as borrowed-closure tasks, vs the sequential loop (t1 —
/// `mix_pooled` at 1 lane IS the sequential loop). Bit-identical at any
/// lane count; only the wall clock moves.
fn bench_mix_pooled(filter: &Option<String>) {
    let n = 16usize;
    let p_dim = 262_144usize;
    let mut rng = Rng::new(9);
    let g = topology::random_connected(n, 0.4, &mut rng);
    let pm = ConsensusMatrix::metropolis_full(&g);
    let init: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..p_dim).map(|_| rng.normal() as f32).collect())
        .collect();
    let mut t1_mean = None;
    for threads in [1usize, 2, 4] {
        let name = format!("mix/pooled-n16_p256k-t{threads}");
        if !wants(filter, &name) {
            continue;
        }
        let pool = EnginePool::tasks_only(threads).unwrap();
        let mut bufs = ParamBuffers::from_initial(init.clone());
        let mut r = bench(&name, 20, || bufs.mix_pooled(&pm, &pool).unwrap());
        if threads == 1 {
            t1_mean = Some(r.mean_ns);
        }
        r.throughput = match t1_mean {
            Some(base) if threads > 1 => Some(format!("{:.2}x vs t1", base / r.mean_ns)),
            _ => None,
        };
        print_result(&r);
    }
}

fn bench_metropolis(filter: &Option<String>) {
    for n in [6usize, 16, 64] {
        let name = format!("metropolis/n{n}");
        if !wants(filter, &name) {
            continue;
        }
        let mut rng = Rng::new(1);
        let g = topology::random_connected(n, 0.3, &mut rng);
        let mut flip = false;
        let r = bench(&name, 200, || {
            let active: Vec<bool> = (0..n).map(|i| (i % 2 == 0) ^ flip).collect();
            flip = !flip;
            let p = ConsensusMatrix::metropolis(&g, &active);
            std::hint::black_box(p.n);
        });
        print_result(&r);
    }
}

fn bench_dtur(filter: &Option<String>) {
    let name = "dtur/step_n16";
    if !wants(filter, name) {
        return;
    }
    let mut rng = Rng::new(2);
    let g = topology::random_connected(16, 0.3, &mut rng);
    let mut dtur = Dtur::new(&g);
    let model = StragglerModel::homogeneous(16, Dist::ShiftedExp { base: 0.05, rate: 20.0 });
    let r = bench(name, 500, || {
        let t = model.sample_iteration(&mut rng);
        std::hint::black_box(dtur.step(&t).theta);
    });
    print_result(&r);
}

fn grad_fixture(meta: &ModelMeta, seed: u64) -> (Vec<f32>, AnyBatch, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut data = gaussian_mixture(
        &MixtureSpec::mnist_like(meta.dim, meta.batch * 2),
        &mut rng,
    );
    data.classes = meta.classes;
    for y in data.y.iter_mut() {
        *y %= meta.classes as u32;
    }
    let batch = AnyBatch::Dense(BatchSampler::new(seed).sample(&data, meta.batch));
    let w = meta.init_params(&mut rng);
    let g = vec![0.0f32; meta.param_count];
    (w, batch, g)
}

fn bench_native_grad(filter: &Option<String>) {
    let cases = [
        ("grad/native-lrm_d64_b256", ModelMeta::lrm(64, 10, 256)),
        ("grad/native-mlp2_d64_b256", ModelMeta::mlp2(64, 256, 10, 256)),
        (
            "grad/native-mlp2_d256_b1024",
            ModelMeta::mlp2(256, 256, 10, 1024),
        ),
    ];
    for (name, meta) in cases {
        if !wants(filter, name) {
            continue;
        }
        let (w, batch, mut g) = grad_fixture(&meta, 3);
        let mut eng = NativeEngine::new(meta.clone()).unwrap();
        let mut r = bench(name, 20, || {
            std::hint::black_box(eng.grad_into(&w, &batch, &mut g).unwrap());
        });
        let flops = grad_flops(&meta);
        r.throughput = Some(format!("{:.2} GFLOP/s", flops / r.mean_ns));
        print_result(&r);
    }
}

/// Approximate FLOPs of one fwd+bwd (GEMMs only).
fn grad_flops(meta: &ModelMeta) -> f64 {
    let b = meta.batch as f64;
    let d = meta.dim as f64;
    let c = meta.classes as f64;
    match meta.kind {
        dybw::model::ModelKind::Lrm => 3.0 * 2.0 * b * d * c,
        dybw::model::ModelKind::Mlp2 => {
            let h = meta.hidden as f64;
            // fwd: bdh + bhh + bhc ; bwd: ~2x
            3.0 * 2.0 * (b * d * h + b * h * h + b * h * c)
        }
        _ => 0.0,
    }
}

#[cfg(not(feature = "pjrt"))]
fn bench_pjrt_grad(_filter: &Option<String>) {
    println!("(skipping grad/pjrt-*: built without --features pjrt)");
}

#[cfg(feature = "pjrt")]
fn bench_pjrt_grad(filter: &Option<String>) {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(set) = dybw::runtime::ArtifactSet::load(&dir) else {
        println!("(skipping grad/pjrt-*: run `make artifacts`)");
        return;
    };
    for name_art in ["lrm_d64_c10_b256", "mlp2_d64_h256_c10_b256"] {
        let name = format!("grad/pjrt-{name_art}");
        if !wants(filter, &name) {
            continue;
        }
        let art = set.get(name_art).unwrap();
        let client = dybw::runtime::shared_client().unwrap();
        let model = dybw::runtime::LoadedModel::compile(art, client).unwrap();
        let (w, batch, mut g) = grad_fixture(&model.meta, 4);
        let mut r = bench(&name, 20, || {
            std::hint::black_box(model.grad_into(&w, &batch, &mut g).unwrap());
        });
        let flops = grad_flops(&model.meta);
        r.throughput = Some(format!("{:.2} GFLOP/s", flops / r.mean_ns));
        print_result(&r);
    }
}

fn bench_end_to_end(filter: &Option<String>) {
    for (name, algo) in [
        ("iter/cb-dybw", Algorithm::CbDybw),
        ("iter/cb-full", Algorithm::CbFull),
        ("iter/ps-sync", Algorithm::PsSync),
    ] {
        if !wants(filter, name) {
            continue;
        }
        let mut s = Setup::default();
        s.algo = algo;
        s.backend = Backend::Native;
        s.threads = 1; // hot-path baseline: one gradient at a time
        s.train_n = 6_000;
        s.test_n = 1_024;
        s.train.iters = 10;
        s.train.eval_every = 0;
        let r = bench(name, 8, || {
            let mut trainer = s.build_sim().unwrap();
            let h = trainer.run().unwrap();
            std::hint::black_box(h.iters.len());
        });
        // report per-iteration cost (10 iterations per sample, ignoring
        // the fixed setup cost which dominates small runs)
        println!(
            "{:<34} mean {:>10}  (~{} per training iteration incl. setup)",
            name,
            fmt_ns(r.mean_ns),
            fmt_ns(r.mean_ns / 10.0)
        );
    }

    // sim-driver wall clock on the acceptance workload: 16 workers on the
    // 2NN, sequential (t1) vs pooled (t4).
    let mut base_mean = None;
    for threads in [1usize, 4] {
        let name = format!("sim/mlp-16w-t{threads}");
        if !wants(filter, &name) {
            continue;
        }
        let mut s = Setup::default();
        s.algo = Algorithm::CbDybw;
        s.backend = Backend::Native;
        s.workers = 16;
        s.threads = threads;
        s.model = "mlp2_d64_h256_c10_b256".into();
        s.train_n = 8_192;
        s.test_n = 512;
        s.train.iters = 4;
        s.train.eval_every = 0;
        let mut trainer = s.build_sim().unwrap();
        let r = bench(&name, 5, || {
            let h = trainer.run().unwrap();
            std::hint::black_box(h.iters.len());
        });
        if threads == 1 {
            base_mean = Some(r.mean_ns);
        }
        println!(
            "{:<34} mean {:>10}{}",
            name,
            fmt_ns(r.mean_ns),
            match base_mean {
                Some(base) if threads > 1 =>
                    format!("  [{:.2}x vs sequential]", base / r.mean_ns),
                _ => String::new(),
            }
        );
    }
}
