//! Offline stand-in for the `anyhow` crate (the subset `dybw` uses).
//!
//! The repository builds with zero external dependencies; this vendored
//! workspace member provides the same surface the real crate would:
//!
//! - [`Error`] — an opaque, `Send + Sync` error value with a message
//! - [`Result`] — `std::result::Result` defaulted to [`Error`]
//! - [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros
//! - a blanket `From<E: std::error::Error>` so `?` converts freely
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket `From` impl
//! coherent. Swap this path dependency for crates.io `anyhow` at any time;
//! no call site changes.

use std::fmt;

/// Opaque error: a rendered message (context is folded in eagerly).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error {
            msg: msg.to_string(),
        }
    }

    /// Prefix the error with higher-level context.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// The coherence trick the real anyhow uses: `Error` itself does not
// implement `std::error::Error`, so this blanket impl cannot overlap the
// reflexive `From<Error> for Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a message, a format string, or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(
                "condition failed: `{}`",
                stringify!($cond)
            ));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/real/path/zzz")?;
        Ok(())
    }

    fn guarded(x: usize) -> Result<usize> {
        ensure!(x < 10, "x too large: {x}");
        ensure!(x != 7);
        if x == 3 {
            bail!("three is right out");
        }
        Ok(x)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn macros_build_messages() {
        assert_eq!(anyhow!("plain").to_string(), "plain");
        let v = 3;
        assert_eq!(anyhow!("v = {v}").to_string(), "v = 3");
        assert_eq!(anyhow!("v = {}", v + 1).to_string(), "v = 4");
        let from_display = anyhow!(String::from("boxed"));
        assert_eq!(from_display.to_string(), "boxed");
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(guarded(2).unwrap(), 2);
        assert!(guarded(11).unwrap_err().to_string().contains("too large"));
        assert!(guarded(7).unwrap_err().to_string().contains("x != 7"));
        assert!(guarded(3).unwrap_err().to_string().contains("three"));
    }

    #[test]
    fn context_prefixes() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner");
    }
}
