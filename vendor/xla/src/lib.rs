//! Offline API stub of the `xla` crate (PJRT bindings).
//!
//! The `dybw` crate's `runtime` module is written against the real
//! `xla` crate (PJRT C API bindings over XLA). That crate needs a
//! multi-gigabyte native `xla_extension` download, which this offline
//! environment cannot provide. This stub mirrors the *exact* API surface
//! `dybw::runtime` consumes so that `cargo build --features pjrt` still
//! type-checks the whole runtime path; every constructor returns a clear
//! runtime error instead of touching PJRT.
//!
//! To run real artifacts, replace the `vendor/xla` path dependency with
//! the actual `xla` crate — no `dybw` source changes are needed.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` (implements `std::error::Error`, so
/// `?` converts into `anyhow::Error` at the call sites).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "PJRT unavailable in this build: {what} (offline `xla` stub; \
         point the workspace `xla` dependency at a real xla-rs checkout)"
    ))
}

/// Element dtypes the runtime uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// PJRT client handle (Rc-backed and thread-local in the real crate).
#[derive(Debug, Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Host-side literal (dense tensor + shape).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(unavailable("Literal::to_tuple2"))
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(unavailable("Literal::get_first_element"))
    }

    pub fn copy_raw_to<T>(&self, _out: &mut [T]) -> Result<()> {
        Err(unavailable("Literal::copy_raw_to"))
    }
}

/// Device buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable pinned to a client.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Parsed HLO module (text form; see `dybw::runtime` docs for why text).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let _ = path.as_ref();
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper accepted by `PjRtClient::compile`.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}
