#!/usr/bin/env bash
# Observability smoke: exercise `--obs-dir` end to end on both drivers
# and hold the telemetry contract — attaching an observer must never
# change a run's exported history.
#
#   1. DES: the committed ring scenario runs twice with the same seed,
#      once plain and once with `--obs-dir`; the event logs, reports
#      (minus the telemetry pointer line) and summary JSONs must match
#      byte for byte, and the recorded trace must parse (JSONL line by
#      line, Chrome trace.json, metrics.json) and feed `dybw obs report`.
#   2. Live: a 4-worker in-process reference vs a 4-worker TCP cluster
#      (one leader + four `dybw worker` processes, leader and worker 0
#      both recording telemetry); exported histories must match byte for
#      byte and both obs dirs must validate.
#
# Deterministic exports land under <out-dir>; logs, addresses, and obs
# dirs (which contain wall-clock timings) go to <out-dir>.scratch.
set -euo pipefail

out_dir="${1:?usage: obs_smoke.sh <out-dir>}"
bin="${DYBW_BIN:-target/release/dybw}"
scratch="${out_dir}.scratch"
mkdir -p "$out_dir" "$scratch"

check_jsonl() {
  python3 - "$1" <<'EOF'
import json, sys
n = 0
with open(sys.argv[1]) as f:
    for line in f:
        line = line.strip()
        if line:
            json.loads(line)
            n += 1
assert n > 0, "empty " + sys.argv[1]
EOF
}

check_chrome() {
  python3 - "$1" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    j = json.load(f)
ev = j.get("traceEvents")
assert isinstance(ev, list) and ev, "no traceEvents in " + sys.argv[1]
EOF
}

check_obs_dir() {
  check_jsonl "$1/trace.jsonl"
  check_chrome "$1/trace.json"
  python3 -c "import json,sys; json.load(open(sys.argv[1]))" "$1/metrics.json"
}

# --- 1. DES: plain vs --obs-dir, byte-identical artifacts -------------
"$bin" des run --scenario scenarios/ring-smoke.json \
  --out-dir "$out_dir/des_plain" --export-events "$scratch/des_plain.log" \
  > "$scratch/des_plain.txt"
"$bin" des run --scenario scenarios/ring-smoke.json \
  --out-dir "$out_dir/des_obs" --export-events "$scratch/des_obs.log" \
  --obs-dir "$scratch/obs_des" > "$scratch/des_obs.txt"

cmp "$scratch/des_plain.log" "$scratch/des_obs.log"
diff -r "$out_dir/des_plain" "$out_dir/des_obs"
# the observed run's report differs only by the telemetry pointer line
diff <(grep -v telemetry "$scratch/des_plain.txt") \
     <(grep -v telemetry "$scratch/des_obs.txt")

check_obs_dir "$scratch/obs_des"
"$bin" obs report "$scratch/obs_des" > "$scratch/report_des.txt"
grep -q 'dybw/worker-' "$scratch/report_des.txt"

# --- 2. Live: in-process reference vs observed 4-worker TCP cluster ---
live_flags=(--workers 4 --topology complete --model lrm_d16_c10_b64
  --train-n 2000 --test-n 512 --iters 8 --eval-every 4 --seed 2021
  --time-scale 0.05 --watchdog 120 --prefix obs)

"$bin" live "${live_flags[@]}" --out-dir "$out_dir/live_ref" \
  > "$scratch/live_ref.log" 2>&1

addr_file="$scratch/addr.txt"
rm -f "$addr_file"
"$bin" live "${live_flags[@]}" --out-dir "$out_dir/live_obs" \
  --listen 127.0.0.1:0 --addr-file "$addr_file" \
  --obs-dir "$scratch/obs_live" > "$scratch/leader.log" 2>&1 &
leader=$!

for _ in $(seq 1 100); do
  [ -s "$addr_file" ] && break
  sleep 0.1
done
if [ ! -s "$addr_file" ]; then
  echo "leader never published an address" >&2
  cat "$scratch/leader.log" >&2
  exit 1
fi
addr="$(cat "$addr_file")"

pids=()
for j in 0 1 2 3; do
  extra=()
  if [ "$j" -eq 0 ]; then
    extra=(--obs-dir "$scratch/obs_w0")
  fi
  "$bin" worker --connect "$addr" --retry-secs 30 "${extra[@]}" \
    > "$scratch/worker$j.log" 2>&1 &
  pids+=($!)
done

fail=0
wait "$leader" || fail=1
for p in "${pids[@]}"; do
  wait "$p" || fail=1
done
if [ "$fail" -ne 0 ]; then
  for log in leader worker0 worker1 worker2 worker3; do
    echo "--- $log.log" >&2
    cat "$scratch/$log.log" >&2
  done
  exit 1
fi

cmp "$out_dir/live_ref/obs.iters.csv" "$out_dir/live_obs/obs.iters.csv"
cmp "$out_dir/live_ref/obs.evals.csv" "$out_dir/live_obs/obs.evals.csv"
diff "$out_dir/live_ref/obs.json" "$out_dir/live_obs/obs.json"

check_obs_dir "$scratch/obs_live"
check_obs_dir "$scratch/obs_w0"
"$bin" obs report "$scratch/obs_live" > "$scratch/report_live.txt"
grep -q 'leader' "$scratch/report_live.txt"
"$bin" obs report "$scratch/obs_w0" > "$scratch/report_w0.txt"
grep -q 'worker-0' "$scratch/report_w0.txt"

echo "obs smoke OK: telemetry recorded, histories unchanged"
