#!/usr/bin/env bash
# Two-process socket smoke: one `dybw live --listen` leader plus two
# `dybw worker` processes on loopback run a short seeded job and leave
# the exported history under <out-dir>. The socket-smoke CI job runs
# this twice and byte-compares the results against each other AND
# against the same seed run in-process — the transport must never
# change the recorded history.
#
# Only the history exports land in <out-dir>; the listen address and
# process logs go to <out-dir>.scratch so `diff -r` between two runs
# compares deterministic bytes only.
set -euo pipefail

out_dir="${1:?usage: socket_smoke.sh <out-dir>}"
bin="${DYBW_BIN:-target/release/dybw}"
scratch="${out_dir}.scratch"
addr_file="$scratch/addr.txt"
mkdir -p "$out_dir" "$scratch"
rm -f "$addr_file"

"$bin" live \
  --workers 2 --topology complete --model lrm_d16_c10_b64 \
  --train-n 2000 --test-n 512 --iters 8 --eval-every 4 --seed 2021 \
  --time-scale 0.05 --watchdog 120 \
  --listen 127.0.0.1:0 --addr-file "$addr_file" \
  --out-dir "$out_dir" --prefix smoke > "$scratch/leader.log" 2>&1 &
leader=$!

# wait for the leader to bind and publish its ephemeral port
for _ in $(seq 1 100); do
  [ -s "$addr_file" ] && break
  sleep 0.1
done
if [ ! -s "$addr_file" ]; then
  echo "leader never published an address" >&2
  cat "$scratch/leader.log" >&2
  exit 1
fi
addr="$(cat "$addr_file")"

"$bin" worker --connect "$addr" --retry-secs 30 > "$scratch/worker0.log" 2>&1 &
w0=$!
"$bin" worker --connect "$addr" --retry-secs 30 > "$scratch/worker1.log" 2>&1 &
w1=$!

fail=0
wait "$leader" || fail=1
wait "$w0" || fail=1
wait "$w1" || fail=1
if [ "$fail" -ne 0 ]; then
  for log in leader worker0 worker1; do
    echo "--- $log.log" >&2
    cat "$scratch/$log.log" >&2
  done
  exit 1
fi
echo "socket smoke OK: $(ls "$out_dir" | tr '\n' ' ')"
