#!/usr/bin/env bash
# Fault-tolerance smoke for the live TCP driver, two modes:
#
#   relaunch — kill -9 one worker right after its first checkpoint, then
#              relaunch it with --resume: it restores its local state,
#              re-runs the handshake, and the leader resyncs it with
#              StateSync.
#   chaos    — the leader injects a scheduled kill + recovery from
#              scenarios/reconnect-smoke.json; the severed worker claims
#              its slot back through its --rejoin-secs loop.
#
# Either way the run must complete and the exported history must be
# byte-identical to the same-seed uninterrupted run (the reconnect-smoke
# CI job asserts this): while a worker is down the leader computes that
# slot's updates locally from the same seeded source, so losing and
# regaining a worker never changes the recorded bytes.
#
# Only the history exports land in <out-dir>; the listen address,
# checkpoints, and process logs go to <out-dir>.scratch.
set -euo pipefail

out_dir="${1:?usage: reconnect_smoke.sh <out-dir> <relaunch|chaos>}"
mode="${2:?usage: reconnect_smoke.sh <out-dir> <relaunch|chaos>}"
bin="${DYBW_BIN:-target/release/dybw}"
scratch="${out_dir}.scratch"
addr_file="$scratch/addr.txt"
ckpt_dir="$scratch/ckpt"
mkdir -p "$out_dir" "$scratch"
rm -rf "$ckpt_dir"
rm -f "$addr_file"

setup=(--workers 3 --topology complete --model lrm_d16_c10_b64
       --train-n 2000 --test-n 512 --iters 20 --eval-every 5 --seed 2021)

leader_flags=(--time-scale 3 --watchdog 120 --heartbeat 1)
if [ "$mode" = chaos ]; then
  leader_flags+=(--chaos scenarios/reconnect-smoke.json)
fi

"$bin" live "${setup[@]}" "${leader_flags[@]}" \
  --listen 127.0.0.1:0 --addr-file "$addr_file" \
  --out-dir "$out_dir" --prefix reconnect > "$scratch/leader.log" 2>&1 &
leader=$!

# wait for the leader to bind and publish its ephemeral port
for _ in $(seq 1 100); do
  [ -s "$addr_file" ] && break
  sleep 0.1
done
if [ ! -s "$addr_file" ]; then
  echo "leader never published an address" >&2
  cat "$scratch/leader.log" >&2
  exit 1
fi
addr="$(cat "$addr_file")"

worker() {
  local id="$1"
  shift
  "$bin" worker --connect "$addr" --worker-id "$id" \
    --retry-secs 30 --rejoin-secs 30 "$@"
}

worker 0 > "$scratch/worker0.log" 2>&1 &
w0=$!
worker 1 > "$scratch/worker1.log" 2>&1 &
w1=$!
worker 2 --ckpt-dir "$ckpt_dir" --ckpt-every 3 > "$scratch/worker2.log" 2>&1 &
w2=$!

w2b=""
if [ "$mode" = relaunch ]; then
  # wait for worker 2's first checkpoint, then kill it without ceremony
  for _ in $(seq 1 200); do
    ls "$ckpt_dir"/ckpt-*.dybw > /dev/null 2>&1 && break
    sleep 0.1
  done
  if ! ls "$ckpt_dir"/ckpt-*.dybw > /dev/null 2>&1; then
    echo "worker 2 never checkpointed" >&2
    cat "$scratch/worker2.log" >&2
    kill "$leader" "$w0" "$w1" "$w2" 2> /dev/null || true
    exit 1
  fi
  kill -9 "$w2"
  wait "$w2" || true
  worker 2 --ckpt-dir "$ckpt_dir" --ckpt-every 3 --resume \
    > "$scratch/worker2b.log" 2>&1 &
  w2b=$!
fi

fail=0
wait "$leader" || fail=1
wait "$w0" || fail=1
wait "$w1" || fail=1
if [ "$mode" = relaunch ]; then
  wait "$w2b" || fail=1
else
  wait "$w2" || fail=1
fi
if [ "$fail" -ne 0 ]; then
  for log in "$scratch"/*.log; do
    echo "--- $log" >&2
    cat "$log" >&2
  done
  exit 1
fi

# the fault actually happened and was survived, not silently skipped
grep -q 'degraded mode' "$scratch/leader.log"
if [ "$mode" = relaunch ]; then
  grep -q 'restored checkpoint' "$scratch/worker2b.log"
else
  grep -q 'rejoined at draw' "$scratch/worker1.log"
fi
echo "reconnect smoke ($mode) OK: $(ls "$out_dir" | tr '\n' ' ')"
