//! Quickstart: train with dynamic backup workers in ~30 lines.
//!
//! Builds the paper's default setting — 6 workers on a random connected
//! graph, LRM on a synthetic MNIST-like dataset, at least one straggler
//! per iteration — runs cb-DyBW and the cb-Full baseline, and prints the
//! head-to-head the paper reports.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

// Config structs are mutated field-by-field after `Default::default()`.
#![allow(clippy::field_reassign_with_default)]

use dybw::coordinator::setup::Setup;
use dybw::coordinator::Algorithm;
use dybw::metrics::summary::Comparison;

fn main() -> anyhow::Result<()> {
    let mut setup = Setup::default(); // 6 workers, random graph, LRM, stragglers on
    setup.train.iters = 150;
    setup.train.eval_every = 10;
    setup.train_n = 12_000;
    setup.test_n = 2_048;

    // --- the paper's algorithm ------------------------------------------
    setup.algo = Algorithm::CbDybw;
    println!("training cb-DyBW ({} iters, {} workers)...", setup.train.iters, setup.workers);
    let dybw = setup.build_sim()?.run()?;

    // --- the full-participation baseline ----------------------------------
    setup.algo = Algorithm::CbFull;
    println!("training cb-Full baseline...");
    let full = setup.build_sim()?.run()?;

    // --- the comparison the paper plots ------------------------------------
    println!("\n{}", Comparison::new(&dybw, &full, 0.55).render());
    let e = dybw.final_eval().unwrap();
    println!(
        "cb-DyBW final: test error {:.1}%, loss {:.4}, mean backup workers {:.2}",
        e.test_error * 100.0,
        e.test_loss,
        dybw.mean_backup_workers()
    );
    Ok(())
}
