//! Topology + scale study: Corollary 2/3's linear speedup, per topology.
//!
//! Trains cb-DyBW at N = 4..16 workers on three graph families and
//! reports iterations-to-target (theory: ∝ 1/N) together with the
//! per-iteration time (denser graphs wait on more links; DTUR keeps θ(k)
//! tied to the *fastest* path link either way).
//!
//! ```bash
//! cargo run --release --example topology_scaling
//! ```

// Config structs are mutated field-by-field after `Default::default()`.
#![allow(clippy::field_reassign_with_default)]

use dybw::coordinator::setup::Setup;
use dybw::coordinator::Algorithm;
use dybw::graph::topology::Topology;

fn main() -> anyhow::Result<()> {
    let mut base = Setup::default();
    base.algo = Algorithm::CbDybw;
    base.train.iters = 300;
    base.train.eval_every = 5;
    base.train.lr_decay = 1.0;
    base.train_n = 12_000;
    base.test_n = 1_536;
    let target = 0.55;

    for topo in [Topology::Ring, Topology::RandomConnected, Topology::Complete] {
        println!("## topology: {}", topo.name());
        println!(
            "{:>4} | {:>12} {:>8} {:>12} {:>12}",
            "N", "iters->tgt", "N x K", "mean T(k)", "final loss"
        );
        for n in [4usize, 6, 8, 12, 16] {
            let mut s = base.clone();
            s.topology = topo;
            s.workers = n;
            // Corollary 2 schedule: eta = sqrt(N/K)
            s.train.lr0 = (n as f64 / s.train.iters as f64).sqrt().min(0.5);
            let h = s.build_sim()?.run()?;
            let k = h.iters_to_test_loss(target);
            println!(
                "{:>4} | {:>12} {:>8} {:>11.3}s {:>12.4}",
                n,
                k.map(|v| v.to_string()).unwrap_or_else(|| "n/a".into()),
                k.map(|v| (v * n).to_string()).unwrap_or_else(|| "-".into()),
                h.mean_iter_duration(),
                h.final_eval().unwrap().test_loss
            );
        }
        println!();
    }
    println!("(N x K roughly constant = linear speedup; ring needs more");
    println!(" iterations at large N — the beta^NB mixing penalty of Thm. 1)");
    Ok(())
}
