//! End-to-end driver: every layer of the stack on a real workload.
//!
//! This is the system proof: Pallas kernels (L1) inside JAX models (L2),
//! AOT-lowered to HLO, loaded and executed by the Rust PJRT runtime, and
//! driven by the *live* coordinator — one OS thread per worker, real
//! wall-clock stragglers, real termination commands, gradients served in
//! parallel by the multi-lane engine pool. No Python anywhere at runtime.
//!
//! Default workload: the paper's Table-1 2NN (256-256-10) on synthetic
//! MNIST-like data, a few hundred steps, loss curve logged (recorded in
//! EXPERIMENTS.md). `--model tfm_v64_t32_d64_h4_l2_b16` trains the tiny
//! transformer LM instead.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train
//! ```

use std::path::PathBuf;
use std::rc::Rc;

use dybw::coordinator::live::run_live;
use dybw::coordinator::setup::{Backend, Setup};
use dybw::coordinator::{Algorithm, TrainConfig};
use dybw::engine::server::ComputeServer;
use dybw::graph::topology;
use dybw::metrics::export;
use dybw::runtime::{shared_client, ArtifactSet, LoadedModel, PjrtEngine};
use dybw::straggler::{Dist, StragglerModel};
use dybw::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model_name = args
        .iter()
        .position(|a| a == "--model")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| "mlp2_d256_h256_c10_b1024".to_string());
    let iters: usize = args
        .iter()
        .position(|a| a == "--iters")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    let artifacts_dir = PathBuf::from(
        std::env::var("DYBW_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );

    println!("# e2e: live cb-DyBW / PJRT / {model_name} / {iters} steps");

    // ---- data + graph + straggler model (the experiment harness) --------
    let workers = 6;
    let seed = 2021u64;
    let mut setup = Setup {
        workers,
        model: model_name.clone(),
        backend: Backend::Pjrt {
            artifacts_dir: artifacts_dir.clone(),
        },
        train_n: 24_000,
        test_n: 4_096,
        ..Default::default()
    };
    setup.train = TrainConfig {
        iters,
        eval_every: 20,
        seed,
        lr0: 0.2,
        lr_decay: 0.95,
        lr_decay_every: 10,
        ..Default::default()
    };
    let meta = setup.resolve_meta()?;
    setup.train.batch_size = meta.batch; // artifact input shapes are fixed
    // transformer synthesises fewer, longer sequences
    if matches!(meta.kind, dybw::model::ModelKind::Transformer) {
        setup.train_n = 1200;
        setup.test_n = 128;
    }
    let mut rng = Rng::new(seed);
    let graph = topology::build(setup.topology, workers, &mut rng);
    // tasks-only pool: the synthesis fan-out needs lanes, not engines
    // (the PJRT compute server below owns the real engine lanes)
    let data_pool = dybw::engine::EnginePool::tasks_only(setup.resolve_threads())?;
    let (sources, eval_batches) = setup.build_data(&meta, &mut rng, &data_pool)?;
    drop(data_pool);
    let init = meta.init_params(&mut rng);
    println!(
        "model: kind={} P={} batch={}  | graph: {} edges, connected={}",
        meta.kind.name(),
        meta.param_count,
        meta.batch,
        graph.edge_count(),
        graph.is_connected()
    );

    // ---- compute server: one PJRT engine per lane, compiled on-lane ------
    let lanes = setup.resolve_threads();
    let art_dir = artifacts_dir.clone();
    let name = model_name.clone();
    let factory: dybw::engine::EngineFactory = std::sync::Arc::new(move || {
        let art = ArtifactSet::load_family(&art_dir, &name)?;
        let model = LoadedModel::compile(&art, shared_client()?)?;
        Ok(Box::new(PjrtEngine::new(Rc::new(model))) as _)
    });
    let (_server, client) = ComputeServer::spawn(factory, lanes)?;
    println!("PJRT artifacts compiled; compute server up ({lanes} lanes)");

    // ---- straggler model: heterogeneous + forced straggler ----------------
    let straggler = StragglerModel {
        base: Dist::ShiftedExp { base: 0.05, rate: 30.0 },
        worker_scale: (0..workers).map(|_| rng.uniform_in(0.8, 1.25)).collect(),
        persistent: vec![1.0; workers],
        transient_prob: 0.15,
        transient_factor: 5.0,
        force_one_straggler: true,
        outages: Vec::new(),
    };

    // ---- go ---------------------------------------------------------------
    let t0 = std::time::Instant::now();
    let outcome = run_live(
        graph,
        Algorithm::CbDybw,
        setup.train.clone(),
        straggler,
        client,
        sources,
        eval_batches,
        init,
        1.0, // real seconds
    )?;
    let h = &outcome.history;

    println!("\n## loss curve (test set, network-average params)");
    println!("{:>6} {:>10} {:>12} {:>10}", "step", "clock", "test loss", "err %");
    for e in &h.evals {
        println!(
            "{:>6} {:>9.1}s {:>12.4} {:>10.1}",
            e.k,
            e.clock,
            e.test_loss,
            e.test_error * 100.0
        );
    }
    println!("\n## run stats");
    println!("  wall time            : {:.1}s (incl. eval)", outcome.wall_seconds);
    println!("  training virtual time: {:.1}s", h.total_time());
    println!("  mean iter duration   : {:.3}s", h.mean_iter_duration());
    println!("  mean backup workers  : {:.2}", h.mean_backup_workers());
    let first = h.evals.first().unwrap();
    let last = h.evals.last().unwrap();
    println!(
        "  test loss {:.4} -> {:.4} ({} evals), error {:.1}% -> {:.1}%",
        first.test_loss,
        last.test_loss,
        h.evals.len(),
        first.test_error * 100.0,
        last.test_error * 100.0
    );
    export::write_csv(h, &PathBuf::from("results"), "e2e")?;
    export::write_json(h, &PathBuf::from("results"), "e2e")?;
    println!("  (full curves -> results/e2e.*.csv)");
    anyhow::ensure!(
        last.test_loss < first.test_loss,
        "e2e training failed to reduce loss"
    );
    println!("\ne2e OK — all three layers composed (elapsed {:.1}s)", t0.elapsed().as_secs_f64());
    Ok(())
}
