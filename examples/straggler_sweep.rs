//! Straggler-severity sweep: when do dynamic backup workers pay off?
//!
//! Sweeps the transient-straggler slowdown factor and the compute-time
//! tail (shifted-exponential vs heavy-tailed Pareto) and reports the
//! total-time speedup of cb-DyBW over cb-Full — §1's "which effect
//! prevails?" question, answered quantitatively.
//!
//! ```bash
//! cargo run --release --example straggler_sweep
//! ```

// Config structs are mutated field-by-field after `Default::default()`.
#![allow(clippy::field_reassign_with_default)]

use dybw::coordinator::setup::Setup;
use dybw::coordinator::Algorithm;
use dybw::straggler::Dist;

fn run(setup: &Setup, algo: Algorithm) -> anyhow::Result<dybw::metrics::RunHistory> {
    let mut s = setup.clone();
    s.algo = algo;
    s.build_sim()?.run()
}

fn main() -> anyhow::Result<()> {
    let mut base = Setup::default();
    base.train.iters = 150;
    base.train.eval_every = 15;
    base.train_n = 9_000;
    base.test_n = 1_536;

    println!("## sweep 1: transient slowdown factor (shifted-exp base)");
    println!(
        "{:>9} | {:>11} {:>11} {:>9} | {:>10}",
        "slowdown", "dybw time", "full time", "speedup", "dybw err%"
    );
    for factor in [1.0, 2.0, 4.0, 8.0, 16.0] {
        let mut s = base.clone();
        s.straggler_factor = factor;
        s.force_straggler = factor > 1.0;
        let a = run(&s, Algorithm::CbDybw)?;
        let b = run(&s, Algorithm::CbFull)?;
        println!(
            "{:>8}x | {:>10.1}s {:>10.1}s {:>8.2}x | {:>10.1}",
            factor,
            a.total_time(),
            b.total_time(),
            b.total_time() / a.total_time(),
            a.final_eval().unwrap().test_error * 100.0
        );
    }

    println!("\n## sweep 2: compute-time tail shape (no forced stragglers)");
    println!(
        "{:>22} | {:>11} {:>11} {:>9}",
        "distribution", "dybw time", "full time", "speedup"
    );
    let dists: [(&str, Dist); 4] = [
        ("deterministic 0.12s", Dist::Deterministic { base: 0.12 }),
        ("uniform [0.06,0.18]", Dist::Uniform { lo: 0.06, hi: 0.18 }),
        ("shifted-exp 0.06+e25", Dist::ShiftedExp { base: 0.06, rate: 25.0 }),
        ("pareto xm=0.07 a=1.8", Dist::Pareto { xm: 0.07, alpha: 1.8 }),
    ];
    for (name, dist) in dists {
        let mut s = base.clone();
        s.straggler_base = dist;
        s.straggler_factor = 1.0;
        s.force_straggler = false;
        let a = run(&s, Algorithm::CbDybw)?;
        let b = run(&s, Algorithm::CbFull)?;
        println!(
            "{:>22} | {:>10.1}s {:>10.1}s {:>8.2}x",
            name,
            a.total_time(),
            b.total_time(),
            b.total_time() / a.total_time()
        );
    }
    println!("\n(heavier tails -> bigger cb-DyBW advantage: the threshold rule");
    println!(" cuts exactly the order statistics the full barrier waits on)");
    Ok(())
}
