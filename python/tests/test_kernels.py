"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including awkward non-tile-multiple sizes) and
asserts allclose against ``compile.kernels.ref``. This is the CORE
correctness signal for the compute layer: everything above (the L2 model,
the AOT artifacts, the Rust PJRT engine) inherits from these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bias_relu, matmul, softmax_xent
from compile.kernels import ref

DIMS = st.integers(min_value=1, max_value=70)
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def rand(seed, *shape):
    return np.random.RandomState(seed).randn(*shape).astype(np.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=SEEDS)
def test_matmul_matches_ref(m, k, n, seed):
    x, w = rand(seed, m, k), rand(seed + 1, k, n)
    got = matmul(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(
        np.asarray(got), ref.matmul_ref(x, w), rtol=2e-4, atol=2e-4
    )


@pytest.mark.parametrize(
    "m,k,n",
    [(1, 1, 1), (128, 128, 128), (129, 127, 130), (256, 64, 10), (7, 300, 3)],
)
def test_matmul_edge_shapes(m, k, n):
    x, w = rand(0, m, k), rand(1, k, n)
    got = matmul(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(
        np.asarray(got), ref.matmul_ref(x, w), rtol=5e-4, atol=5e-4
    )


@settings(max_examples=10, deadline=None)
@given(m=st.integers(2, 40), k=st.integers(2, 40), n=st.integers(2, 40), seed=SEEDS)
def test_matmul_grad_matches_autodiff_of_ref(m, k, n, seed):
    x, w = rand(seed, m, k), rand(seed + 7, k, n)

    def f_kernel(x, w):
        return jnp.sum(matmul(x, w) ** 2)

    def f_ref(x, w):
        return jnp.sum(ref.matmul_ref(x, w) ** 2)

    gx1, gw1 = jax.grad(f_kernel, argnums=(0, 1))(jnp.asarray(x), jnp.asarray(w))
    gx2, gw2 = jax.grad(f_ref, argnums=(0, 1))(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw2), rtol=1e-3, atol=1e-3)


def test_matmul_zero_inputs():
    x = np.zeros((5, 9), np.float32)
    w = np.zeros((9, 4), np.float32)
    np.testing.assert_array_equal(np.asarray(matmul(jnp.asarray(x), jnp.asarray(w))), 0.0)


def test_matmul_identity():
    x = rand(3, 16, 16)
    eye = np.eye(16, dtype=np.float32)
    np.testing.assert_allclose(
        np.asarray(matmul(jnp.asarray(x), jnp.asarray(eye))), x, rtol=1e-6
    )


# ---------------------------------------------------------------------------
# bias_relu
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(rows=DIMS, cols=DIMS, seed=SEEDS)
def test_bias_relu_matches_ref(rows, cols, seed):
    x, b = rand(seed, rows, cols), rand(seed + 3, cols)
    got = bias_relu(jnp.asarray(x), jnp.asarray(b))
    np.testing.assert_allclose(
        np.asarray(got), ref.bias_relu_ref(x, b), rtol=1e-6, atol=1e-6
    )


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(2, 50), cols=st.integers(2, 50), seed=SEEDS)
def test_bias_relu_grad(rows, cols, seed):
    x, b = rand(seed, rows, cols), rand(seed + 3, cols)

    def f1(x, b):
        return jnp.sum(bias_relu(x, b) * 3.0)

    def f2(x, b):
        return jnp.sum(ref.bias_relu_ref(x, b) * 3.0)

    g1 = jax.grad(f1, argnums=(0, 1))(jnp.asarray(x), jnp.asarray(b))
    g2 = jax.grad(f2, argnums=(0, 1))(jnp.asarray(x), jnp.asarray(b))
    for a, bb in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb), rtol=1e-5, atol=1e-5)


def test_bias_relu_all_negative_is_zero():
    x = -np.abs(rand(0, 8, 8)) - 1.0
    b = np.zeros(8, np.float32)
    np.testing.assert_array_equal(
        np.asarray(bias_relu(jnp.asarray(x), jnp.asarray(b))), 0.0
    )


# ---------------------------------------------------------------------------
# softmax_xent
# ---------------------------------------------------------------------------


def onehot(seed, rows, classes):
    idx = np.random.RandomState(seed).randint(0, classes, size=rows)
    out = np.zeros((rows, classes), np.float32)
    out[np.arange(rows), idx] = 1.0
    return out


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 70), classes=st.integers(2, 40), seed=SEEDS)
def test_softmax_xent_matches_ref(rows, classes, seed):
    z = rand(seed, rows, classes)
    y = onehot(seed + 1, rows, classes)
    got = softmax_xent(jnp.asarray(z), jnp.asarray(y))
    want = ref.softmax_xent_ref(jnp.asarray(z), jnp.asarray(y))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(2, 40), classes=st.integers(2, 20), seed=SEEDS)
def test_softmax_xent_grad(rows, classes, seed):
    z = rand(seed, rows, classes)
    y = onehot(seed + 1, rows, classes)
    g1 = jax.grad(lambda z: softmax_xent(z, jnp.asarray(y)))(jnp.asarray(z))
    g2 = jax.grad(lambda z: ref.softmax_xent_ref(z, jnp.asarray(y)))(jnp.asarray(z))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-6)


def test_softmax_xent_is_shift_invariant():
    z = rand(2, 9, 5)
    y = onehot(3, 9, 5)
    a = softmax_xent(jnp.asarray(z), jnp.asarray(y))
    b = softmax_xent(jnp.asarray(z + 100.0), jnp.asarray(y))
    np.testing.assert_allclose(float(a), float(b), rtol=1e-4)


def test_softmax_xent_extreme_logits_stable():
    z = np.array([[1e4, -1e4], [-1e4, 1e4]], np.float32)
    y = np.eye(2, dtype=np.float32)
    got = float(softmax_xent(jnp.asarray(z), jnp.asarray(y)))
    assert np.isfinite(got) and got < 1e-3


def test_softmax_xent_uniform_logits_is_log_c():
    for c in (2, 10, 33):
        z = np.zeros((4, c), np.float32)
        y = onehot(0, 4, c)
        got = float(softmax_xent(jnp.asarray(z), jnp.asarray(y)))
        np.testing.assert_allclose(got, np.log(c), rtol=1e-5)


# ---------------------------------------------------------------------------
# dtype sweeps (TPU deployment story: bf16 activations through the MXU)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [(16, 16, 16), (33, 17, 9)])
def test_matmul_dtypes(dtype, m, k, n):
    x = jnp.asarray(rand(0, m, k), dtype=dtype)
    w = jnp.asarray(rand(1, k, n), dtype=dtype)
    got = matmul(x, w)
    assert got.dtype == dtype
    want = ref.matmul_ref(x.astype(jnp.float32), w.astype(jnp.float32))
    tol = 5e-5 if dtype == jnp.float32 else 0.15  # bf16: 8-bit mantissa
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bias_relu_dtypes(dtype):
    x = jnp.asarray(rand(2, 12, 8), dtype=dtype)
    b = jnp.asarray(rand(3, 8), dtype=dtype)
    got = bias_relu(x, b)
    assert got.dtype == dtype
    want = ref.bias_relu_ref(x.astype(jnp.float32), b.astype(jnp.float32))
    tol = 1e-6 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want), rtol=tol, atol=tol
    )


def test_matmul_block_boundary_shapes():
    """Shapes straddling the 128 tile edge must not corrupt edges."""
    for m, k, n in [(127, 128, 129), (128, 129, 127), (255, 1, 257)]:
        x, w = rand(4, m, k), rand(5, k, n)
        got = np.asarray(matmul(jnp.asarray(x), jnp.asarray(w)))
        want = x @ w
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
        # explicitly check the last row/col (padding bugs live there)
        np.testing.assert_allclose(got[-1, :], want[-1, :], rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(got[:, -1], want[:, -1], rtol=1e-3, atol=1e-3)
