"""Layer-2 correctness: flat-parameter models vs pure-jnp reference models.

The kernel-backed models (compile.model) must agree with hand-written
pure-jnp versions both in value and in gradient — this is what licenses the
Rust native engine to use the same math as its oracle.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def ref_loss_for(spec: M.ModelSpec):
    layout = spec.layout()

    def lrm(flat, x, y):
        p = layout.unflatten(flat)
        z = x @ p["w"] + p["b"]
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(z) * y, axis=-1))

    def mlp2(flat, x, y):
        p = layout.unflatten(flat)
        h1 = jnp.maximum(x @ p["w1"] + p["b1"], 0.0)
        h2 = jnp.maximum(h1 @ p["w2"] + p["b2"], 0.0)
        z = h2 @ p["w3"] + p["b3"]
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(z) * y, axis=-1))

    return {"lrm": lrm, "mlp2": mlp2}[spec.kind]


def make_batch(spec, seed=0):
    rs = np.random.RandomState(seed)
    if spec.kind == "transformer":
        x = rs.randint(0, spec.vocab, size=(spec.batch, spec.seq)).astype(np.int32)
        yi = rs.randint(0, spec.vocab, size=(spec.batch, spec.seq))
        y = np.eye(spec.vocab, dtype=np.float32)[yi]
    else:
        x = rs.randn(spec.batch, spec.dim).astype(np.float32)
        yi = rs.randint(0, spec.classes, size=spec.batch)
        y = np.eye(spec.classes, dtype=np.float32)[yi]
    return jnp.asarray(x), jnp.asarray(y)


SMALL = [
    M.ModelSpec("t_lrm", "lrm", batch=32, dim=12, classes=5),
    M.ModelSpec("t_mlp2", "mlp2", batch=16, dim=10, classes=4, hidden=24),
]


@pytest.mark.parametrize("spec", SMALL, ids=lambda s: s.name)
def test_loss_matches_reference(spec):
    layout = spec.layout()
    flat = layout.init_flat(jax.random.PRNGKey(1))
    x, y = make_batch(spec)
    got = float(M.loss_fn(spec)(flat, x, y))
    want = float(ref_loss_for(spec)(flat, x, y))
    np.testing.assert_allclose(got, want, rtol=1e-4)


@pytest.mark.parametrize("spec", SMALL, ids=lambda s: s.name)
def test_grad_matches_reference(spec):
    layout = spec.layout()
    flat = layout.init_flat(jax.random.PRNGKey(2))
    x, y = make_batch(spec, seed=3)
    loss1, g1 = M.grad_fn(spec)(flat, x, y)
    loss2, g2 = jax.value_and_grad(ref_loss_for(spec))(flat, x, y)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=2e-3, atol=1e-5)


@pytest.mark.parametrize("spec", SMALL, ids=lambda s: s.name)
def test_sgd_descends(spec):
    """A few SGD steps on a fixed batch must reduce the loss."""
    layout = spec.layout()
    flat = layout.init_flat(jax.random.PRNGKey(3))
    x, y = make_batch(spec, seed=4)
    fn = jax.jit(M.grad_fn(spec))
    loss0, g = fn(flat, x, y)
    for _ in range(5):
        flat = flat - 0.5 * g
        loss, g = fn(flat, x, y)
    assert float(loss) < float(loss0)


def test_layout_roundtrip():
    spec = M.ModelSpec("t", "mlp2", batch=4, dim=6, classes=3, hidden=8)
    layout = spec.layout()
    flat = jnp.arange(layout.total, dtype=jnp.float32)
    p = layout.unflatten(flat)
    # segments tile the vector exactly, in order, no overlap
    off = 0
    for seg in layout.segments:
        v = p[seg.name].reshape(-1)
        np.testing.assert_array_equal(
            np.asarray(v), np.arange(off, off + seg.size, dtype=np.float32)
        )
        off += seg.size
    assert off == layout.total


def test_layout_meta_consistent():
    for spec in M.DEFAULT_SPECS:
        layout = spec.layout()
        meta = layout.meta()
        assert sum(m["size"] for m in meta) == layout.total
        off = 0
        for m in meta:
            assert m["offset"] == off
            assert m["size"] == int(np.prod(m["shape"]))
            off += m["size"]


def test_transformer_param_count():
    spec = M.SPECS_BY_NAME["tfm_v64_t32_d64_h4_l2_b16"]
    layout = spec.layout()
    dm, v, t, L = spec.d_model, spec.vocab, spec.seq, spec.n_layers
    expect = v * dm + t * dm
    expect += L * (4 * dm * dm + 4 * dm + dm * 4 * dm + 4 * dm + 4 * dm * dm + dm)
    expect += 2 * dm + dm * v
    assert layout.total == expect


def test_eval_counts_correct_predictions():
    spec = M.ModelSpec("t", "lrm", batch=8, dim=4, classes=3)
    layout = spec.layout()
    # Zero params -> uniform logits -> argmax = class 0 for every row.
    flat = jnp.zeros((layout.total,))
    x = jnp.asarray(np.random.RandomState(0).randn(8, 4).astype(np.float32))
    yi = np.array([0, 0, 1, 2, 0, 1, 2, 0])
    y = jnp.asarray(np.eye(3, dtype=np.float32)[yi])
    loss, correct = M.eval_fn(spec)(flat, x, y)
    assert float(correct) == float((yi == 0).sum())
    np.testing.assert_allclose(float(loss), math.log(3), rtol=1e-5)
