"""AOT pipeline tests: lowering, metadata, and HLO-text invariants.

These validate the python half of the interchange contract the Rust
runtime (rust/src/runtime) relies on: parameter ordering, tuple outputs,
and parseable HLO text.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

SMOKE = "lrm_d8_c4_b16"


@pytest.fixture(scope="module")
def smoke_hlo():
    return aot.lower_spec(M.SPECS_BY_NAME[SMOKE], "grad")


def test_hlo_text_has_entry(smoke_hlo):
    assert "ENTRY" in smoke_hlo
    assert "HloModule" in smoke_hlo


def _entry_layout(hlo: str) -> str:
    """The `entry_computation_layout={(...)->(...)}` clause of the header."""
    head = hlo[: hlo.index("\n")]
    key = "entry_computation_layout="
    return head[head.index(key) + len(key) :]


def test_hlo_text_parameter_order(smoke_hlo):
    """Entry params must be (params_flat, x, y) in that order."""
    spec = M.SPECS_BY_NAME[SMOKE]
    layout = spec.layout()
    sig = _entry_layout(smoke_hlo).split("->")[0]
    p, x, y = (
        f"f32[{layout.total}]",
        f"f32[{spec.batch},{spec.dim}]",
        f"f32[{spec.batch},{spec.classes}]",
    )
    assert sig.index(p) < sig.index(x) < sig.index(y), sig


def test_hlo_output_is_tuple(smoke_hlo):
    """return_tuple=True -> root is a (loss, grad) tuple."""
    spec = M.SPECS_BY_NAME[SMOKE]
    layout = spec.layout()
    ret = _entry_layout(smoke_hlo).split("->")[1]
    assert ret.strip().startswith("(")  # tuple return type
    assert "f32[]" in ret  # scalar loss
    assert f"f32[{layout.total}]" in ret  # flat gradient


def test_meta_matches_layout():
    for spec in M.DEFAULT_SPECS:
        meta = aot.meta_for(spec)
        layout = spec.layout()
        assert meta["param_count"] == layout.total
        assert len(meta["segments"]) == len(layout.segments)
        assert meta["x_shape"] == list(spec.input_specs()[0].shape)


def test_build_writes_artifact_set(tmp_path):
    aot.build(str(tmp_path), [SMOKE], verbose=False)
    names = sorted(os.listdir(tmp_path))
    assert names == [
        f"{SMOKE}.eval.hlo.txt",
        f"{SMOKE}.grad.hlo.txt",
        f"{SMOKE}.meta.json",
        "manifest.json",
    ]
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["artifacts"][0]["name"] == SMOKE
    meta = json.loads((tmp_path / f"{SMOKE}.meta.json").read_text())
    assert meta["param_count"] == M.SPECS_BY_NAME[SMOKE].layout().total


def test_lowered_grad_executes_and_matches_eager():
    """jit-compiled artifact function == eager function on same inputs."""
    spec = M.SPECS_BY_NAME[SMOKE]
    layout = spec.layout()
    flat = layout.init_flat(jax.random.PRNGKey(0))
    rs = np.random.RandomState(0)
    x = jnp.asarray(rs.randn(spec.batch, spec.dim).astype(np.float32))
    y = jnp.asarray(
        np.eye(spec.classes, dtype=np.float32)[
            rs.randint(0, spec.classes, spec.batch)
        ]
    )
    fn = M.grad_fn(spec)
    l_eager, g_eager = fn(flat, x, y)
    l_jit, g_jit = jax.jit(fn)(flat, x, y)
    np.testing.assert_allclose(float(l_eager), float(l_jit), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g_eager), np.asarray(g_jit), rtol=1e-4, atol=1e-6)
