"""Layer-2 JAX models: the paper's workloads over *flat* parameter vectors.

The Rust coordinator owns model state as a flat ``f32[P]`` vector (that is
what the consensus update (eq. 5-6) averages), so every model here is a pure
function of ``(params_flat, x, y_onehot)``. The segment layout is exported
in the artifact metadata (see aot.py) so the Rust side can initialise and
slice the same vector.

Models (paper §5 / Appendix B):
- ``lrm``  — logistic regression (cross-entropy).
- ``mlp2`` — 2-hidden-layer fully-connected net, Table 1 (256-256-10).
- ``transformer`` — a tiny decoder-only LM, the "modern workload"
  extension exercised by the e2e example (not in the paper's eval; kept
  because the coordinator is model-agnostic and this proves it).

All dense GEMMs route through the Layer-1 Pallas kernels.
"""

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import bias_relu, matmul, softmax_xent


# ---------------------------------------------------------------------------
# Flat parameter layout
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Segment:
    """One named tensor inside the flat parameter vector."""

    name: str
    shape: Tuple[int, ...]
    init: str  # "glorot_uniform" | "zeros" | "normal_scaled"

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


@dataclass
class ParamLayout:
    segments: List[Segment] = field(default_factory=list)

    @property
    def total(self) -> int:
        return sum(s.size for s in self.segments)

    def offsets(self) -> Dict[str, int]:
        out, off = {}, 0
        for s in self.segments:
            out[s.name] = off
            off += s.size
        return out

    def unflatten(self, flat: jax.Array) -> Dict[str, jax.Array]:
        out, off = {}, 0
        for s in self.segments:
            out[s.name] = flat[off : off + s.size].reshape(s.shape)
            off += s.size
        return out

    def init_flat(self, key: jax.Array) -> jax.Array:
        """Reference initialiser (tests only — Rust owns init at runtime)."""
        chunks = []
        for s in self.segments:
            key, sub = jax.random.split(key)
            if s.init == "zeros":
                chunks.append(jnp.zeros((s.size,), jnp.float32))
            elif s.init == "glorot_uniform":
                fan_in = s.shape[0] if len(s.shape) > 1 else s.size
                fan_out = s.shape[-1]
                lim = math.sqrt(6.0 / (fan_in + fan_out))
                chunks.append(
                    jax.random.uniform(
                        sub, (s.size,), jnp.float32, minval=-lim, maxval=lim
                    )
                )
            elif s.init == "normal_scaled":
                scale = 1.0 / math.sqrt(max(1, s.shape[-1]))
                chunks.append(jax.random.normal(sub, (s.size,), jnp.float32) * scale)
            else:
                raise ValueError(f"unknown init {s.init}")
        return jnp.concatenate(chunks)

    def meta(self) -> List[dict]:
        out, off = [], 0
        for s in self.segments:
            out.append(
                {
                    "name": s.name,
                    "shape": list(s.shape),
                    "offset": off,
                    "size": s.size,
                    "init": s.init,
                }
            )
            off += s.size
        return out


# ---------------------------------------------------------------------------
# Model specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelSpec:
    """Static shape description of one artifact family."""

    name: str
    kind: str  # "lrm" | "mlp2" | "transformer"
    batch: int
    # classification models
    dim: int = 0
    classes: int = 0
    hidden: int = 0
    # transformer
    vocab: int = 0
    seq: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_layers: int = 0

    def layout(self) -> ParamLayout:
        if self.kind == "lrm":
            return ParamLayout(
                [
                    Segment("w", (self.dim, self.classes), "glorot_uniform"),
                    Segment("b", (self.classes,), "zeros"),
                ]
            )
        if self.kind == "mlp2":
            h = self.hidden
            return ParamLayout(
                [
                    Segment("w1", (self.dim, h), "glorot_uniform"),
                    Segment("b1", (h,), "zeros"),
                    Segment("w2", (h, h), "glorot_uniform"),
                    Segment("b2", (h,), "zeros"),
                    Segment("w3", (h, self.classes), "glorot_uniform"),
                    Segment("b3", (self.classes,), "zeros"),
                ]
            )
        if self.kind == "transformer":
            dm, v = self.d_model, self.vocab
            segs = [
                Segment("embed", (v, dm), "normal_scaled"),
                Segment("pos", (self.seq, dm), "normal_scaled"),
            ]
            for i in range(self.n_layers):
                p = f"blk{i}."
                segs += [
                    Segment(p + "wq", (dm, dm), "glorot_uniform"),
                    Segment(p + "wk", (dm, dm), "glorot_uniform"),
                    Segment(p + "wv", (dm, dm), "glorot_uniform"),
                    Segment(p + "wo", (dm, dm), "glorot_uniform"),
                    Segment(p + "ln1_g", (dm,), "zeros"),  # stored as gamma-1
                    Segment(p + "ln1_b", (dm,), "zeros"),
                    Segment(p + "w_up", (dm, 4 * dm), "glorot_uniform"),
                    Segment(p + "b_up", (4 * dm,), "zeros"),
                    Segment(p + "w_dn", (4 * dm, dm), "glorot_uniform"),
                    Segment(p + "b_dn", (dm,), "zeros"),
                    Segment(p + "ln2_g", (dm,), "zeros"),
                    Segment(p + "ln2_b", (dm,), "zeros"),
                ]
            segs += [
                Segment("lnf_g", (dm,), "zeros"),
                Segment("lnf_b", (dm,), "zeros"),
                Segment("w_out", (dm, v), "glorot_uniform"),
            ]
            return ParamLayout(segs)
        raise ValueError(f"unknown model kind {self.kind}")

    def input_specs(self) -> Tuple[jax.ShapeDtypeStruct, jax.ShapeDtypeStruct]:
        """(x, y_onehot) example specs for lowering."""
        if self.kind == "transformer":
            x = jax.ShapeDtypeStruct((self.batch, self.seq), jnp.int32)
            y = jax.ShapeDtypeStruct((self.batch, self.seq, self.vocab), jnp.float32)
        else:
            x = jax.ShapeDtypeStruct((self.batch, self.dim), jnp.float32)
            y = jax.ShapeDtypeStruct((self.batch, self.classes), jnp.float32)
        return x, y


# ---------------------------------------------------------------------------
# Forward passes (all GEMMs via Pallas kernels)
# ---------------------------------------------------------------------------


def _lrm_logits(p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    return matmul(x, p["w"]) + p["b"]


def _mlp2_logits(p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    h1 = bias_relu(matmul(x, p["w1"]), p["b1"])
    h2 = bias_relu(matmul(h1, p["w2"]), p["b2"])
    return matmul(h2, p["w3"]) + p["b3"]


def _layernorm(x: jax.Array, g: jax.Array, b: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * (1.0 + g) + b


def _transformer_logits(
    p: Dict[str, jax.Array], x: jax.Array, spec: ModelSpec
) -> jax.Array:
    b, t = x.shape
    dm, nh = spec.d_model, spec.n_heads
    hd = dm // nh
    h = p["embed"][x] + p["pos"][None, :, :]
    mask = jnp.tril(jnp.ones((t, t), jnp.float32))
    neg = jnp.float32(-1e9)
    for i in range(spec.n_layers):
        pre = f"blk{i}."
        hn = _layernorm(h, p[pre + "ln1_g"], p[pre + "ln1_b"])
        flat = hn.reshape(b * t, dm)
        q = matmul(flat, p[pre + "wq"]).reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
        k = matmul(flat, p[pre + "wk"]).reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
        v = matmul(flat, p[pre + "wv"]).reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
        att = jnp.where(mask[None, None, :, :] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b * t, dm)
        h = h + matmul(ctx, p[pre + "wo"]).reshape(b, t, dm)
        hn = _layernorm(h, p[pre + "ln2_g"], p[pre + "ln2_b"])
        up = bias_relu(matmul(hn.reshape(b * t, dm), p[pre + "w_up"]), p[pre + "b_up"])
        dn = matmul(up, p[pre + "w_dn"]) + p[pre + "b_dn"]
        h = h + dn.reshape(b, t, dm)
    h = _layernorm(h, p["lnf_g"], p["lnf_b"])
    return matmul(h.reshape(b * t, dm), p["w_out"]).reshape(b, t, spec.vocab)


def logits_fn(spec: ModelSpec) -> Callable:
    layout = spec.layout()

    def logits(flat: jax.Array, x: jax.Array) -> jax.Array:
        p = layout.unflatten(flat)
        if spec.kind == "lrm":
            return _lrm_logits(p, x)
        if spec.kind == "mlp2":
            return _mlp2_logits(p, x)
        if spec.kind == "transformer":
            return _transformer_logits(p, x, spec)
        raise ValueError(spec.kind)

    return logits


def loss_fn(spec: ModelSpec) -> Callable:
    """(flat, x, y_onehot) -> mean cross-entropy scalar."""
    logits = logits_fn(spec)

    def loss(flat: jax.Array, x: jax.Array, y1h: jax.Array) -> jax.Array:
        z = logits(flat, x)
        if spec.kind == "transformer":
            z = z.reshape(-1, spec.vocab)
            y1h = y1h.reshape(-1, spec.vocab)
        return softmax_xent(z, y1h)

    return loss


def grad_fn(spec: ModelSpec) -> Callable:
    """(flat, x, y_onehot) -> (loss, grad_flat) — the training artifact."""
    vg = jax.value_and_grad(loss_fn(spec))

    def run(flat, x, y1h):
        loss, g = vg(flat, x, y1h)
        return loss, g

    return run


def eval_fn(spec: ModelSpec) -> Callable:
    """(flat, x, y_onehot) -> (loss, n_correct) — the evaluation artifact."""
    logits = logits_fn(spec)

    def run(flat, x, y1h):
        z = logits(flat, x)
        if spec.kind == "transformer":
            zf = z.reshape(-1, spec.vocab)
            yf = y1h.reshape(-1, spec.vocab)
        else:
            zf, yf = z, y1h
        loss = softmax_xent(zf, yf)
        correct = jnp.sum(
            (jnp.argmax(zf, axis=-1) == jnp.argmax(yf, axis=-1)).astype(jnp.float32)
        )
        return loss, correct

    return run


# ---------------------------------------------------------------------------
# Default artifact set (see aot.py / Makefile)
# ---------------------------------------------------------------------------

DEFAULT_SPECS: List[ModelSpec] = [
    # Paper §5: LRM on PCA-reduced MNIST / CIFAR-10 analogues.
    ModelSpec("lrm_d64_c10_b256", "lrm", batch=256, dim=64, classes=10),
    ModelSpec("lrm_d128_c10_b256", "lrm", batch=256, dim=128, classes=10),
    # Paper Table 1: 2NN 256-256-10 (inputs PCA'd to 256 dims).
    ModelSpec("mlp2_d256_h256_c10_b1024", "mlp2", batch=1024, dim=256, classes=10, hidden=256),
    ModelSpec("mlp2_d64_h256_c10_b256", "mlp2", batch=256, dim=64, classes=10, hidden=256),
    # Modern-workload extension for the e2e example.
    ModelSpec(
        "tfm_v64_t32_d64_h4_l2_b16",
        "transformer",
        batch=16,
        vocab=64,
        seq=32,
        d_model=64,
        n_heads=4,
        n_layers=2,
    ),
    # Tiny smoke spec used by tests.
    ModelSpec("lrm_d8_c4_b16", "lrm", batch=16, dim=8, classes=4),
]

SPECS_BY_NAME = {s.name: s for s in DEFAULT_SPECS}
