"""Pure-jnp correctness oracles for the Pallas kernels.

Every Layer-1 kernel has an exact reference here; pytest asserts
``assert_allclose(kernel, ref)`` across a hypothesis-driven sweep of
shapes/dtypes (python/tests/test_kernels.py). The references are also the
ground truth for the Layer-2 model tests and, transitively, for the Rust
native engine (rust/src/model) which re-implements the same math.
"""

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.matmul(x, w)


def bias_relu_ref(x: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.maximum(x + b, 0.0)


def softmax_xent_ref(z: jax.Array, y1h: jax.Array) -> jax.Array:
    """Mean cross-entropy of logits against one-hot labels (stable)."""
    logp = jax.nn.log_softmax(z, axis=-1)
    return -jnp.mean(jnp.sum(logp * y1h, axis=-1))


def dense_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.matmul(x, w) + b


def dense_relu_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.maximum(jnp.matmul(x, w) + b, 0.0)
