"""Layer-1 Pallas fused softmax + cross-entropy kernel.

Fuses the row-wise numerically-stable log-softmax, the cross-entropy
reduction against one-hot labels, and (in the backward kernel) the
``softmax(z) - onehot`` gradient into single VMEM-resident passes — the
classifier-head analogue of the fused loss kernels GPU frameworks ship as
a single CUDA kernel. Rows are tiled along the batch axis; the class axis
stays whole inside a tile (C <= a few thousand fits VMEM comfortably).

Differentiable via ``custom_vjp``; both directions are Pallas kernels.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BROWS = 128  # batch-rows per tile


def _ceil_to(x: int, b: int) -> int:
    return (x + b - 1) // b * b


def _fwd_kernel(z_ref, y_ref, loss_ref):
    """Per-row loss: -log softmax(z)[y]  (stable: shift by row max)."""
    z = z_ref[...]
    y = y_ref[...]
    zmax = jnp.max(z, axis=1, keepdims=True)
    shifted = z - zmax
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=1, keepdims=True))
    logp = shifted - lse  # (rows, C)
    picked = jnp.sum(logp * y, axis=1)  # one-hot select
    loss_ref[...] = -picked


def _bwd_kernel(z_ref, y_ref, g_ref, dz_ref):
    """dz = g[:, None] * (softmax(z) - y) in one fused pass."""
    z = z_ref[...]
    y = y_ref[...]
    g = g_ref[...]
    zmax = jnp.max(z, axis=1, keepdims=True)
    e = jnp.exp(z - zmax)
    p = e / jnp.sum(e, axis=1, keepdims=True)
    dz_ref[...] = g[:, None] * (p - y)


def _pad_rows(a, rows, target):
    return jnp.pad(a, ((0, target - rows),) + ((0, 0),) * (a.ndim - 1))


def _xent_rows(z: jax.Array, y1h: jax.Array) -> jax.Array:
    """Per-example cross-entropy, tiled over batch rows."""
    b, c = z.shape
    br = min(BROWS, _ceil_to(b, 8))
    bp = _ceil_to(b, br)
    zp = _pad_rows(z, b, bp)
    yp = _pad_rows(y1h, b, bp)
    out = pl.pallas_call(
        _fwd_kernel,
        grid=(bp // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bp,), z.dtype),
        interpret=True,
    )(zp, yp)
    return out[:b]


@jax.custom_vjp
def softmax_xent(z: jax.Array, y1h: jax.Array) -> jax.Array:
    """Mean cross-entropy of logits ``z`` (B,C) against one-hot ``y1h``."""
    return jnp.mean(_xent_rows(z, y1h))


def _sx_fwd(z, y1h):
    return softmax_xent(z, y1h), (z, y1h)


def _sx_bwd(res, g):
    z, y1h = res
    b, c = z.shape
    br = min(BROWS, _ceil_to(b, 8))
    bp = _ceil_to(b, br)
    zp = _pad_rows(z, b, bp)
    yp = _pad_rows(y1h, b, bp)
    # The mean() folds 1/B into every row's upstream gradient.
    grow = jnp.full((bp,), g / b, dtype=z.dtype)
    dz = pl.pallas_call(
        _bwd_kernel,
        grid=(bp // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, c), z.dtype),
        interpret=True,
    )(zp, yp, grow)
    return dz[:b], None


softmax_xent.defvjp(_sx_fwd, _sx_bwd)
