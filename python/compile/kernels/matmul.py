"""Layer-1 Pallas tiled matmul kernel.

The paper's compute hot-spot is the dense layer fwd/bwd (LRM / 2NN /
transformer blocks all reduce to GEMM). On the authors' testbed this ran as
cuBLAS GEMMs; the TPU adaptation tiles for VMEM and targets the MXU
systolic array: the grid walks (M/bm, N/bn) output tiles and the innermost
loop streams K-blocks HBM->VMEM through a float32 accumulator held in VMEM
scratch (see DESIGN.md §Hardware-Adaptation).

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls; interpret mode lowers the same schedule to
plain HLO so the Rust runtime can load it.

Autodiff: ``pallas_call`` is not differentiable, so ``matmul`` carries a
``custom_vjp`` whose backward pass is two more tiled matmuls
(dx = g @ w^T, dw = x^T @ g) — the same kernel, re-entered.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes. 128 matches the MXU systolic array edge; on small problems we
# shrink to the (padded) problem size so interpret-mode does not waste work.
BM, BN, BK = 128, 128, 128


def _ceil_to(x: int, b: int) -> int:
    return (x + b - 1) // b * b


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile; grid = (M/bm, N/bn, K/bk).

    The K dimension is the innermost grid axis, so the output tile (held in
    VMEM across K-steps because its index_map ignores the K axis) serves as
    the float32 accumulator. This is the canonical MXU schedule: weight
    blocks stream through the systolic array while the accumulator stays
    resident in VMEM.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU-style f32 partial products; rounded to the output dtype on the
    # cross-K accumulate (a dedicated f32 VMEM scratch accumulator would
    # avoid the intermediate rounding for bf16 outputs — noted in
    # DESIGN.md §Hardware-Adaptation).
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _matmul_raw(x: jax.Array, w: jax.Array, bm: int, bn: int, bk: int) -> jax.Array:
    """Tiled x @ w with explicit padding to block multiples."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {w.shape}"
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else w
    nk = kp // bk
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Differentiable tiled Pallas matmul: ``x @ w``."""
    return _matmul_raw(x, w, BM, BN, BK)


def _matmul_fwd(x, w):
    return matmul(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    dx = _matmul_raw(g, w.T, BM, BN, BK)
    dw = _matmul_raw(x.T, g, BM, BN, BK)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)
