"""Layer-1 Pallas kernels (build-time only; lowered into the model HLO).

Exports the differentiable kernel entry points used by the Layer-2 model:

- :func:`matmul` — tiled MXU-schedule matmul (custom_vjp).
- :func:`bias_relu` — fused bias + ReLU epilogue (custom_vjp).
- :func:`softmax_xent` — fused stable log-softmax + cross-entropy (custom_vjp).

All run under ``interpret=True`` so the lowered HLO executes on the CPU
PJRT plugin the Rust runtime loads (see module docstrings + DESIGN.md
§Hardware-Adaptation for the TPU mapping).
"""

from .elementwise import bias_relu
from .matmul import matmul
from .softmax_xent import softmax_xent

__all__ = ["matmul", "bias_relu", "softmax_xent"]
