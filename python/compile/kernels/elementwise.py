"""Layer-1 Pallas fused elementwise kernels: bias + ReLU.

``bias_relu(x, b) = max(x + b, 0)`` fused into one VMEM pass (forward) and
one masked pass (backward). On GPU this is the classic epilogue fusion into
the GEMM; on TPU the VPU applies it tile-by-tile as output blocks leave the
MXU — we keep it a separate kernel so the GEMM kernel stays a pure MXU
schedule, and document the epilogue-fusion trade-off in DESIGN.md.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BROWS = 128


def _ceil_to(x: int, b: int) -> int:
    return (x + b - 1) // b * b


def _fwd_kernel(x_ref, b_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...] + b_ref[...], 0.0)


def _bwd_kernel(x_ref, b_ref, g_ref, dx_ref):
    mask = (x_ref[...] + b_ref[...]) > 0.0
    dx_ref[...] = jnp.where(mask, g_ref[...], 0.0)


def _tiled_call(kernel, args, out_shape, rows, cols):
    br = min(BROWS, _ceil_to(rows, 8))
    rp = _ceil_to(rows, br)
    padded = [
        jnp.pad(a, ((0, rp - rows), (0, 0))) if a.ndim == 2 else a for a in args
    ]
    specs = [
        pl.BlockSpec((br, cols), lambda i: (i, 0))
        if a.ndim == 2
        else pl.BlockSpec((cols,), lambda i: (0,))
        for a in args
    ]
    out = pl.pallas_call(
        kernel,
        grid=(rp // br,),
        in_specs=specs,
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, cols), out_shape.dtype),
        interpret=True,
    )(*padded)
    return out[:rows]


@jax.custom_vjp
def bias_relu(x: jax.Array, b: jax.Array) -> jax.Array:
    """Fused ``relu(x + b)`` over (B, H) activations with (H,) bias."""
    rows, cols = x.shape
    return _tiled_call(
        _fwd_kernel, [x, b], jax.ShapeDtypeStruct((rows, cols), x.dtype), rows, cols
    )


def _br_fwd(x, b):
    return bias_relu(x, b), (x, b)


def _br_bwd(res, g):
    x, b = res
    rows, cols = x.shape
    dx = _tiled_call(
        _bwd_kernel,
        [x, b, g],
        jax.ShapeDtypeStruct((rows, cols), x.dtype),
        rows,
        cols,
    )
    return dx, jnp.sum(dx, axis=0)


bias_relu.defvjp(_br_fwd, _br_bwd)
