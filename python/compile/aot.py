"""AOT compiler: lower Layer-2 models to HLO text artifacts for Rust.

This is the *only* place Python touches the training stack; it runs once at
build time (``make artifacts``). For every :class:`~compile.model.ModelSpec`
it emits

- ``<name>.grad.hlo.txt`` — (params, x, y1h) -> (loss, grad_flat)
- ``<name>.eval.hlo.txt`` — (params, x, y1h) -> (loss, n_correct)
- ``<name>.meta.json``    — shapes, flat-parameter segment layout, inits
- plus a ``manifest.json`` over the whole set.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_spec(spec: M.ModelSpec, which: str) -> str:
    """Lower the grad or eval entry point of one spec to HLO text."""
    layout = spec.layout()
    pspec = jax.ShapeDtypeStruct((layout.total,), jax.numpy.float32)
    xspec, yspec = spec.input_specs()
    fn = M.grad_fn(spec) if which == "grad" else M.eval_fn(spec)
    lowered = jax.jit(fn).lower(pspec, xspec, yspec)
    return to_hlo_text(lowered)


def meta_for(spec: M.ModelSpec) -> dict:
    layout = spec.layout()
    xspec, yspec = spec.input_specs()
    return {
        "name": spec.name,
        "kind": spec.kind,
        "batch": spec.batch,
        "dim": spec.dim,
        "classes": spec.classes,
        "hidden": spec.hidden,
        "vocab": spec.vocab,
        "seq": spec.seq,
        "d_model": spec.d_model,
        "n_heads": spec.n_heads,
        "n_layers": spec.n_layers,
        "param_count": layout.total,
        "segments": layout.meta(),
        "x_shape": list(xspec.shape),
        "x_dtype": str(xspec.dtype),
        "y_shape": list(yspec.shape),
        "y_dtype": str(yspec.dtype),
        "outputs": {
            "grad": ["loss f32[]", f"grad f32[{layout.total}]"],
            "eval": ["loss f32[]", "n_correct f32[]"],
        },
    }


def build(out_dir: str, names: list, verbose: bool = True) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"artifacts": []}
    for name in names:
        spec = M.SPECS_BY_NAME[name]
        meta = meta_for(spec)
        for which in ("grad", "eval"):
            path = os.path.join(out_dir, f"{name}.{which}.hlo.txt")
            text = lower_spec(spec, which)
            with open(path, "w") as f:
                f.write(text)
            if verbose:
                print(f"  wrote {path} ({len(text) / 1024:.0f} KiB)")
        mpath = os.path.join(out_dir, f"{name}.meta.json")
        with open(mpath, "w") as f:
            json.dump(meta, f, indent=2)
        manifest["artifacts"].append(
            {
                "name": name,
                "meta": f"{name}.meta.json",
                "grad": f"{name}.grad.hlo.txt",
                "eval": f"{name}.eval.hlo.txt",
                "param_count": meta["param_count"],
            }
        )
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"manifest: {len(manifest['artifacts'])} artifact families")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--models",
        nargs="*",
        default=[s.name for s in M.DEFAULT_SPECS],
        choices=[s.name for s in M.DEFAULT_SPECS],
    )
    args = ap.parse_args()
    build(args.out_dir, args.models)


if __name__ == "__main__":
    sys.exit(main())
